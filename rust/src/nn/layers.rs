//! Layer implementations: dense, hashed (the paper's contribution),
//! masked-dense (RER) and low-rank (LRD).
//!
//! Each layer owns its stored parameters as a flat `Vec<f32>` whose
//! layout matches the corresponding artifact parameter in
//! `artifacts/manifest.json`, so parameters can be moved between the
//! native engine and the PJRT runtime freely.

use crate::hash::{bucket_sign, hash_gaussian, hash_uniform, layer_seeds};
use crate::tensor::Matrix;
use crate::util::rng::Pcg32;

/// What kind of weight structure a layer uses.
#[derive(Debug, Clone, PartialEq)]
pub enum LayerKind {
    /// Standard dense `W (n×m)` + bias `b (n)`.
    Dense,
    /// HashedNets: `K` real weights, virtual `V (n×(m+1))` decompressed
    /// via `V_ij = ξ(i,j) · w_{h(i,j)}` (paper Eq. 7).
    Hashed { k: usize },
    /// Random Edge Removal: dense-but-masked `(n×(m+1))`, hash mask.
    Masked { k: usize },
    /// Low-Rank Decomposition: learned output-side `W (n×r)`, fixed
    /// hash-Gaussian input projection `U (r×(m+1))` (V = W·U).
    LowRank { r: usize },
}

/// One network layer: `m` inputs (excluding bias) → `n` outputs.
#[derive(Debug, Clone)]
pub struct Layer {
    pub m: usize,
    pub n: usize,
    pub kind: LayerKind,
    pub index: usize,     // layer number (selects hash seeds)
    pub seed_base: u32,
    /// Stored parameters, artifact layout:
    /// Dense: `[W (n*m), b (n)]`; Hashed: `[w (k)]`;
    /// Masked: `[Wm (n*(m+1))]`; LowRank: `[Wl (n*r)]`.
    pub params: Vec<f32>,
    /// Optional decompressed-id cache for the hashed hot path
    /// (`(bucket, sign_bit)` per virtual cell). Built on demand.
    cache: Option<(Vec<u32>, Vec<f32>)>,
}

impl Layer {
    pub fn new(m: usize, n: usize, kind: LayerKind, index: usize, seed_base: u32) -> Layer {
        let n_params = match kind {
            LayerKind::Dense => n * m + n,
            LayerKind::Hashed { k } => k,
            LayerKind::Masked { .. } => n * (m + 1),
            LayerKind::LowRank { r } => n * r,
        };
        Layer { m, n, kind, index, seed_base, params: vec![0.0; n_params], cache: None }
    }

    /// He-style init matching `model.py`'s `ParamSpec.init_std`.
    pub fn init(&mut self, rng: &mut Pcg32) {
        let m = self.m;
        match self.kind {
            LayerKind::Dense => {
                let std = (2.0 / m as f32).sqrt();
                let nm = self.n * m;
                rng.fill_normal(&mut self.params[..nm], std);
                self.params[nm..].iter_mut().for_each(|b| *b = 0.0);
            }
            LayerKind::Hashed { .. } => {
                let std = (2.0 / (m + 1) as f32).sqrt();
                rng.fill_normal(&mut self.params, std);
            }
            LayerKind::Masked { k } => {
                let keep = k as f32 / ((m + 1) * self.n) as f32;
                let std = (2.0 / (keep * (m + 1) as f32).max(1.0)).sqrt();
                rng.fill_normal(&mut self.params, std);
            }
            LayerKind::LowRank { r } => {
                let std = (2.0 / r as f32).sqrt();
                rng.fill_normal(&mut self.params, std);
            }
        }
    }

    pub fn n_stored(&self) -> usize {
        match self.kind {
            LayerKind::Masked { k } => k, // logical storage (kept edges)
            _ => self.params.len(),
        }
    }

    /// Ensure the hashed-layer decompression cache is built.
    fn build_hashed_cache(&mut self) {
        let (m1, n) = (self.m + 1, self.n);
        let LayerKind::Hashed { k } = self.kind else { unreachable!() };
        if self.cache.is_none() {
            let (s_h, s_xi) = layer_seeds(self.index as u32, self.seed_base);
            let mut ids = Vec::with_capacity(n * m1);
            let mut signs = Vec::with_capacity(n * m1);
            for i in 0..n as u32 {
                for j in 0..m1 as u32 {
                    let (b, sg) = bucket_sign(i, j, m1 as u32, k as u32, s_h, s_xi);
                    ids.push(b);
                    signs.push(sg);
                }
            }
            self.cache = Some((ids, signs));
        }
    }

    /// Borrow the decompression cache (build first).
    fn hashed_cache(&mut self) -> (&[u32], &[f32]) {
        self.build_hashed_cache();
        let (ids, signs) = self.cache.as_ref().unwrap();
        (ids, signs)
    }

    /// LRD's fixed random input projection `U (r × (m+1))`,
    /// hash-generated with std `1/sqrt(m+1)` (mirrors `model._lrd_layer`).
    fn lrd_fixed_u(&self, r: usize) -> Matrix {
        let m1 = self.m + 1;
        let (s_u, _) = layer_seeds(2000 + self.index as u32, self.seed_base);
        let std = (m1 as f32).powf(-0.5);
        let mut u = Matrix::zeros(r, m1);
        for (idx, out) in u.data.iter_mut().enumerate() {
            *out = hash_gaussian(idx as u32, std, s_u);
        }
        u
    }

    /// Materialize the effective weight matrix `V (n × m_eff)` where
    /// `m_eff = m` for Dense and `m+1` (bias column) otherwise.
    /// Used by tests, the compressor, and the simple backward path.
    pub fn virtual_matrix(&mut self) -> Matrix {
        let (m1, n) = (self.m + 1, self.n);
        match self.kind {
            LayerKind::Dense => {
                let mut v = Matrix::zeros(n, self.m);
                v.data.copy_from_slice(&self.params[..n * self.m]);
                v
            }
            LayerKind::Hashed { .. } => {
                let params = self.params.clone();
                self.build_hashed_cache();
                let (ids, signs) = self.cache.as_ref().unwrap();
                let mut v = Matrix::zeros(n, m1);
                for (out, (&id, &sg)) in v.data.iter_mut().zip(ids.iter().zip(signs)) {
                    *out = params[id as usize] * sg;
                }
                v
            }
            LayerKind::Masked { k } => {
                let keep = k as f32 / (m1 * n) as f32;
                let (s_mask, _) = layer_seeds(1000 + self.index as u32, self.seed_base);
                let mut v = Matrix::zeros(n, m1);
                for (idx, (out, &p)) in v.data.iter_mut().zip(&self.params).enumerate() {
                    let u = hash_uniform(idx as u32, s_mask);
                    *out = if u < keep { p } else { 0.0 };
                }
                v
            }
            LayerKind::LowRank { r } => {
                // V (n×(m+1)) = W (n×r) · U (r×(m+1)), U fixed
                let u = self.lrd_fixed_u(r);
                let w = Matrix::from_vec(n, r, self.params.clone());
                w.matmul(&u)
            }
        }
    }

    /// Forward: `z = a·Vᵀ (+ b)`; `a` is `(B × m)` un-augmented.
    pub fn forward(&mut self, a: &Matrix) -> Matrix {
        assert_eq!(a.cols, self.m);
        match self.kind {
            LayerKind::Dense => {
                let n = self.n;
                let w = Matrix::from_vec(n, self.m, self.params[..n * self.m].to_vec());
                let b = &self.params[n * self.m..];
                let mut z = a.matmul_nt(&w);
                for r in 0..z.rows {
                    for (zv, &bv) in z.row_mut(r).iter_mut().zip(b) {
                        *zv += bv;
                    }
                }
                z
            }
            LayerKind::Hashed { .. } => self.forward_hashed(a),
            _ => {
                let v = self.virtual_matrix();
                a.augment_ones().matmul_nt(&v)
            }
        }
    }

    /// The native decompress-on-the-fly hot path (paper Eq. 8): never
    /// materializes V; reads `w` through the id cache.
    fn forward_hashed(&mut self, a: &Matrix) -> Matrix {
        let (m1, n) = (self.m + 1, self.n);
        let params = std::mem::take(&mut self.params);
        self.build_hashed_cache();
        let (ids, signs) = self.cache.as_ref().unwrap();
        let a_aug = a.augment_ones();
        let mut z = Matrix::zeros(a.rows, n);
        for b in 0..a.rows {
            let arow = a_aug.row(b);
            let zrow = z.row_mut(b);
            for i in 0..n {
                let ids_row = &ids[i * m1..(i + 1) * m1];
                let signs_row = &signs[i * m1..(i + 1) * m1];
                let mut acc = 0.0f32;
                for j in 0..m1 {
                    acc += params[ids_row[j] as usize] * signs_row[j] * arow[j];
                }
                zrow[i] = acc;
            }
        }
        self.params = params;
        z
    }

    /// Backward: given `delta (B×n)` (dL/dz) and input `a (B×m)`,
    /// returns `da (B×m)` and accumulates the stored-parameter gradient
    /// into `grad` (same layout as `params`).
    pub fn backward(&mut self, a: &Matrix, delta: &Matrix, grad: &mut [f32]) -> Matrix {
        assert_eq!(grad.len(), self.params.len());
        match self.kind {
            LayerKind::Dense => {
                let n = self.n;
                let m = self.m;
                let w = Matrix::from_vec(n, m, self.params[..n * m].to_vec());
                // dW = deltaᵀ·a ; db = Σ_b delta
                let dw = delta.matmul_tn(a); // (n×m)
                grad[..n * m].iter_mut().zip(&dw.data).for_each(|(g, &d)| *g += d);
                for b in 0..delta.rows {
                    for (g, &d) in grad[n * m..].iter_mut().zip(delta.row(b)) {
                        *g += d;
                    }
                }
                delta.matmul(&w)
            }
            LayerKind::Hashed { .. } => self.backward_hashed(a, delta, grad),
            LayerKind::Masked { k } => {
                let v = self.virtual_matrix();
                let da_aug = delta.matmul(&v);
                let g_dense = delta.matmul_tn(&a.augment_ones()); // (n×(m+1))
                let m1 = self.m + 1;
                let keep = k as f32 / (m1 * self.n) as f32;
                let (s_mask, _) = layer_seeds(1000 + self.index as u32, self.seed_base);
                for (idx, (g, &gd)) in grad.iter_mut().zip(&g_dense.data).enumerate() {
                    if hash_uniform(idx as u32, s_mask) < keep {
                        *g += gd;
                    }
                }
                da_aug.drop_last_col()
            }
            LayerKind::LowRank { r } => {
                let v = self.virtual_matrix();
                let da_aug = delta.matmul(&v);
                // h = a_aug·Uᵀ (B×r); dW = deltaᵀ·h (n×r)
                let u = self.lrd_fixed_u(r);
                let h = a.augment_ones().matmul_nt(&u);
                let dw = delta.matmul_tn(&h); // (n×r)
                grad.iter_mut().zip(&dw.data).for_each(|(g, &d)| *g += d);
                da_aug.drop_last_col()
            }
        }
    }

    /// Hashed backward (paper Eqs. 9 & 12), fused over the id cache.
    fn backward_hashed(&mut self, a: &Matrix, delta: &Matrix, grad: &mut [f32]) -> Matrix {
        let (m1, n, m) = (self.m + 1, self.n, self.m);
        let params = std::mem::take(&mut self.params);
        self.build_hashed_cache();
        let (ids, signs) = self.cache.as_ref().unwrap();
        let a_aug = a.augment_ones();
        let mut da = Matrix::zeros(a.rows, m);
        for b in 0..a.rows {
            let arow = a_aug.row(b);
            let drow = delta.row(b);
            let darow = da.row_mut(b);
            for i in 0..n {
                let d = drow[i];
                if d == 0.0 {
                    continue;
                }
                let ids_row = &ids[i * m1..(i + 1) * m1];
                let signs_row = &signs[i * m1..(i + 1) * m1];
                for j in 0..m1 {
                    let v = params[ids_row[j] as usize] * signs_row[j];
                    if j < m {
                        darow[j] += d * v;
                    }
                    // Eq. 12: dw_{h(i,j)} += ξ(i,j) a_j δ_i
                    grad[ids_row[j] as usize] += signs_row[j] * arow[j] * d;
                }
            }
        }
        self.params = params;
        da
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rand_matrix(rows: usize, cols: usize, rng: &mut Pcg32) -> Matrix {
        Matrix::from_fn(rows, cols, |_, _| rng.normal())
    }

    fn mk(kind: LayerKind, m: usize, n: usize) -> Layer {
        let mut l = Layer::new(m, n, kind, 0, crate::hash::DEFAULT_SEED_BASE);
        let mut rng = Pcg32::new(9, 9);
        l.init(&mut rng);
        l
    }

    #[test]
    fn hashed_forward_matches_virtual_matrix() {
        let mut l = mk(LayerKind::Hashed { k: 13 }, 10, 6);
        let mut rng = Pcg32::new(1, 1);
        let a = rand_matrix(4, 10, &mut rng);
        let z_fast = l.forward(&a);
        let v = l.virtual_matrix();
        let z_ref = a.augment_ones().matmul_nt(&v);
        for (x, y) in z_fast.data.iter().zip(&z_ref.data) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn hashed_weight_sharing_actually_shares() {
        let mut l = mk(LayerKind::Hashed { k: 3 }, 8, 8);
        let v = l.virtual_matrix();
        // only 3 distinct |values| may occur
        let mut mags: Vec<u32> = v.data.iter().map(|x| x.abs().to_bits()).collect();
        mags.sort_unstable();
        mags.dedup();
        assert!(mags.len() <= 3, "found {} distinct magnitudes", mags.len());
    }

    fn finite_diff_check(mut layer: Layer) {
        let mut rng = Pcg32::new(2, 2);
        let a = rand_matrix(3, layer.m, &mut rng);
        let co = rand_matrix(3, layer.n, &mut rng); // cotangent

        let loss = |l: &mut Layer| -> f32 {
            let z = l.forward(&a);
            z.data.iter().zip(&co.data).map(|(z, c)| z * c).sum()
        };
        let mut grad = vec![0.0f32; layer.params.len()];
        let _da = layer.backward(&a, &co, &mut grad);
        let eps = 1e-2f32;
        // spot-check a handful of parameters
        let step = (layer.params.len() / 7).max(1);
        for p in (0..layer.params.len()).step_by(step) {
            let orig = layer.params[p];
            layer.params[p] = orig + eps;
            let lp = loss(&mut layer);
            layer.params[p] = orig - eps;
            let lm = loss(&mut layer);
            layer.params[p] = orig;
            let fd = (lp - lm) / (2.0 * eps);
            assert!(
                (fd - grad[p]).abs() < 2e-2 * (1.0 + fd.abs()),
                "param {p}: fd {fd} vs ad {}",
                grad[p]
            );
        }
    }

    #[test]
    fn gradients_dense() {
        finite_diff_check(mk(LayerKind::Dense, 7, 5));
    }

    #[test]
    fn gradients_hashed() {
        finite_diff_check(mk(LayerKind::Hashed { k: 11 }, 7, 5));
    }

    #[test]
    fn gradients_masked() {
        finite_diff_check(mk(LayerKind::Masked { k: 20 }, 7, 5));
    }

    #[test]
    fn gradients_lowrank() {
        finite_diff_check(mk(LayerKind::LowRank { r: 3 }, 7, 5));
    }

    #[test]
    fn input_gradient_matches_fd() {
        let mut layer = mk(LayerKind::Hashed { k: 9 }, 6, 4);
        let mut rng = Pcg32::new(3, 3);
        let mut a = rand_matrix(2, 6, &mut rng);
        let co = rand_matrix(2, 4, &mut rng);
        let mut grad = vec![0.0f32; layer.params.len()];
        let da = layer.backward(&a.clone(), &co, &mut grad);
        let eps = 1e-2f32;
        for probe in [(0usize, 0usize), (1, 3), (0, 5)] {
            let orig = a.at(probe.0, probe.1);
            *a.at_mut(probe.0, probe.1) = orig + eps;
            let zp: f32 = layer.forward(&a).data.iter().zip(&co.data).map(|(z, c)| z * c).sum();
            *a.at_mut(probe.0, probe.1) = orig - eps;
            let zm: f32 = layer.forward(&a).data.iter().zip(&co.data).map(|(z, c)| z * c).sum();
            *a.at_mut(probe.0, probe.1) = orig;
            let fd = (zp - zm) / (2.0 * eps);
            let ad = da.at(probe.0, probe.1);
            assert!((fd - ad).abs() < 2e-2 * (1.0 + fd.abs()), "{fd} vs {ad}");
        }
    }

    #[test]
    fn masked_layer_keeps_roughly_k_edges() {
        let (m, n, k) = (20usize, 15usize, 60usize);
        let mut l = mk(LayerKind::Masked { k }, m, n);
        let v = l.virtual_matrix();
        let nz = v.data.iter().filter(|&&x| x != 0.0).count();
        assert!((nz as f32 - k as f32).abs() < 0.35 * k as f32, "nz={nz}");
        assert_eq!(l.n_stored(), k);
    }

    #[test]
    fn lowrank_matrix_has_rank_r() {
        let mut l = mk(LayerKind::LowRank { r: 2 }, 9, 7);
        let v = l.virtual_matrix(); // 7×10, rank ≤ 2
        // crude rank check: any 3 rows are linearly dependent → the
        // 3rd singular-ish direction vanishes. Use Gram determinant.
        let rows = [v.row(0), v.row(2), v.row(5)];
        let gram: Vec<f32> = (0..3)
            .flat_map(|i| (0..3).map(move |j| (i, j)))
            .map(|(i, j)| rows[i].iter().zip(rows[j]).map(|(a, b)| a * b).sum())
            .collect();
        let det = gram[0] * (gram[4] * gram[8] - gram[5] * gram[7])
            - gram[1] * (gram[3] * gram[8] - gram[5] * gram[6])
            + gram[2] * (gram[3] * gram[7] - gram[4] * gram[6]);
        let scale = gram[0] * gram[4] * gram[8] + 1e-6;
        assert!((det / scale).abs() < 1e-3, "rank>2? det/scale={}", det / scale);
    }
}
