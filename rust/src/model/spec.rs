//! [`Method`] and [`ModelSpec`]: the typed, validated, JSON
//! round-trippable identity of one model.

use super::ModelError;
use crate::nn::LayerKind;
use crate::util::json::{num, obj, Json};

/// How a hashed embedding bag reduces the rows of one bag.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BagMode {
    /// `z = Σ_r V_r` over the bag's rows.
    Sum,
    /// `z = (Σ_r V_r) / |bag|`; an empty bag is the zero vector.
    Mean,
}

impl BagMode {
    pub fn parse(s: &str) -> Result<BagMode, ModelError> {
        match s {
            "sum" => Ok(BagMode::Sum),
            "mean" => Ok(BagMode::Mean),
            other => Err(ModelError::InvalidSpec(format!(
                "unknown bag mode '{other}' (expected sum or mean)"
            ))),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            BagMode::Sum => "sum",
            BagMode::Mean => "mean",
        }
    }
}

/// The model family — the paper's HashedNet variants plus the four
/// baselines of §6, and the hashed embedding bag (the sparse-lookup
/// workload of ROADMAP item 3). Replaces the stringly-typed
/// `"hashnet" | "nn" | …` matches that used to be duplicated across
/// the coordinator.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Method {
    /// HashedNet (paper Eq. 7): `K` real weights per layer, hash-shared.
    Hashnet,
    /// HashedNet trained with dark-knowledge soft targets.
    HashnetDk,
    /// Dense baseline (equivalent stored size).
    Nn,
    /// Dense baseline trained with dark knowledge.
    Dk,
    /// Random Edge Removal (Cireşan et al.): hash-masked dense.
    Rer,
    /// Low-Rank Decomposition (Denil et al.): learned `W`, fixed `U`.
    Lrd,
    /// Hashed embedding bag: a `num_categories × dim` virtual lookup
    /// table backed by `k` real weights via the Eq. 7 hash mapping.
    /// The virtual table is **never materialized** — rows decompress
    /// lazily per lookup, so `num_categories` can be millions while
    /// resident memory stays `O(k)`.
    HashedEmbedding {
        /// Virtual row count (categorical vocabulary size).
        num_categories: usize,
        /// Embedding width (columns of the virtual table).
        dim: usize,
        /// Real-weight budget `K` (the only stored tensor).
        k: usize,
        /// Bag reduction: sum or mean.
        mode: BagMode,
    },
    /// Block-structured HashedNet (Structured Multi-Hashing / Functional
    /// Hashing direction): `tile.0 × tile.1` tiles of the virtual matrix
    /// hash to contiguous runs of the stored weights with one ξ sign per
    /// tile ([`crate::hash::TilePlan`]), so the forward/backward kernels
    /// run contiguous 8-lane SIMD loops instead of per-cell gathers.
    /// Same per-layer budget semantics as [`Method::Hashnet`].
    HashedTile {
        /// Tile shape `(rows, cols)` in virtual cells; `cols` should be
        /// a multiple of the SIMD width (8) for the vector kernels to
        /// run full-width.
        tile: (usize, usize),
    },
}

impl Method {
    /// Every method, in the paper's table order.
    pub const ALL: [Method; 6] = [
        Method::Rer,
        Method::Lrd,
        Method::Nn,
        Method::Dk,
        Method::Hashnet,
        Method::HashnetDk,
    ];

    /// Fallible parse of the wire/manifest name. The one place in the
    /// system where a method string is interpreted.
    ///
    /// `"hashed_embedding"` and `"hashed_tile"` are *not* parseable
    /// here: their variants carry shape fields (`num_categories`/`dim`/
    /// `k`/`mode`, resp. `tile`) that a bare name cannot supply —
    /// [`ModelSpec::from_json`] derives them from the spec's
    /// `dims`/`budgets`/`mode`/`tile` keys instead.
    pub fn parse(s: &str) -> Result<Method, ModelError> {
        match s {
            "hashnet" => Ok(Method::Hashnet),
            "hashnet_dk" => Ok(Method::HashnetDk),
            "nn" => Ok(Method::Nn),
            "dk" => Ok(Method::Dk),
            "rer" => Ok(Method::Rer),
            "lrd" => Ok(Method::Lrd),
            other => Err(ModelError::UnknownMethod(other.to_string())),
        }
    }

    /// The canonical name (inverse of [`Method::parse`] for the
    /// field-free methods).
    pub fn as_str(&self) -> &'static str {
        match self {
            Method::Hashnet => "hashnet",
            Method::HashnetDk => "hashnet_dk",
            Method::Nn => "nn",
            Method::Dk => "dk",
            Method::Rer => "rer",
            Method::Lrd => "lrd",
            Method::HashedEmbedding { .. } => "hashed_embedding",
            Method::HashedTile { .. } => "hashed_tile",
        }
    }

    /// Parse a `"THxTW"` tile-shape string (e.g. `"1x8"`, `"8x8"`) —
    /// shared by [`ModelSpec::from_json`] and the CLI's `--tile` flag.
    pub fn parse_tile(s: &str) -> Result<(usize, usize), ModelError> {
        let bad = || {
            ModelError::InvalidSpec(format!(
                "bad tile '{s}' (expected ROWSxCOLS, e.g. 1x8 or 8x8)"
            ))
        };
        let (th, tw) = s.split_once('x').ok_or_else(bad)?;
        let th: usize = th.trim().parse().map_err(|_| bad())?;
        let tw: usize = tw.trim().parse().map_err(|_| bad())?;
        if th == 0 || tw == 0 {
            return Err(bad());
        }
        Ok((th, tw))
    }

    /// Whether training this method consumes teacher soft targets.
    pub fn uses_soft_targets(&self) -> bool {
        matches!(self, Method::Dk | Method::HashnetDk)
    }

    /// The layer structure this method uses for a `(m → n)` layer with
    /// stored budget `budget` — the single source of the mapping that
    /// `coordinator::native` used to hard-code (and `panic!` on).
    ///
    /// Panics for [`Method::HashedEmbedding`]: embedding specs have no
    /// dense-activation layers ([`ModelSpec::layer_kinds`] is empty for
    /// them), and building a `LayerKind::Hashed` here would eagerly
    /// materialize a per-cell `HashPlan` over the virtual table.
    pub fn layer_kind(&self, n: usize, budget: usize) -> LayerKind {
        match self {
            Method::Hashnet | Method::HashnetDk => LayerKind::Hashed { k: budget },
            Method::HashedTile { tile } => LayerKind::HashedTile { k: budget, tile: *tile },
            Method::Nn | Method::Dk => LayerKind::Dense,
            Method::Rer => LayerKind::Masked { k: budget },
            Method::Lrd => {
                let r = (budget as f64 / n as f64).round().max(1.0) as usize;
                LayerKind::LowRank { r }
            }
            Method::HashedEmbedding { .. } => {
                panic!("hashed_embedding has no per-layer kind (use nn::EmbedBag)")
            }
        }
    }
}

impl std::fmt::Display for Method {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// The self-describing identity of one model: everything needed to
/// rebuild its network skeleton (and so to interpret a parameter
/// vector) anywhere.
///
/// This is the paper's storage observation turned into an API: a
/// HashedNet is fully determined by its virtual `dims`, the per-layer
/// real-weight budgets `K^ℓ` and the hash seed — the `(h, ξ)` mappings
/// of §4.2 are reconstructed from `seed_base` wherever the spec lands,
/// so the spec plus a parameter vector *is* the model.
///
/// Invariants enforced by [`ModelSpec::new`] / [`ModelSpec::validate`]:
/// at least two dims, one budget per layer, no zero dims or budgets.
///
/// # Examples
///
/// The paper's MNIST configuration at compression 1/8, round-tripped
/// through JSON (the bundle's header encoding):
///
/// ```
/// use hashednets::model::{Method, ModelSpec};
///
/// let spec = ModelSpec::new(
///     "mnist_1-8",
///     Method::Hashnet,
///     vec![784, 100, 10], // virtual layer widths (Eq. 7's n × (m+1) per layer)
///     vec![9_812, 126],   // per-layer budgets K^ℓ — the stored weights
///     0x9E37_79B9,        // seed base for the h / ξ hash pairs (§4.2)
///     50,                 // preferred batch (the paper's minibatch)
/// ).unwrap();
///
/// // 785·100 + 101·10 virtual cells backed by 9 938 real weights ≈ 1/8
/// assert_eq!(spec.virtual_params(), 79_510);
/// assert_eq!(spec.stored_params(), 9_938);
/// assert!((spec.compression() - 0.125).abs() < 1e-3);
///
/// let back = ModelSpec::from_json_str(&spec.to_json_string()).unwrap();
/// assert_eq!(back, spec);
/// ```
#[derive(Debug, Clone, PartialEq)]
pub struct ModelSpec {
    /// Human-readable model name (registry key when serving).
    pub name: String,
    pub method: Method,
    /// Virtual layer widths, input first: `[n_in, h_1, …, n_out]`.
    pub dims: Vec<usize>,
    /// Per-layer stored-parameter budgets (`K` for hashed layers;
    /// kept-edge count for RER; `r·n` for LRD; ignored by dense).
    pub budgets: Vec<usize>,
    /// Base seed of the layer hash functions (`hash::layer_seeds`).
    pub seed_base: u32,
    /// Preferred serving batch size (the dynamic batcher's max).
    pub batch: usize,
}

impl ModelSpec {
    /// Construct and validate.
    pub fn new(
        name: impl Into<String>,
        method: Method,
        dims: Vec<usize>,
        budgets: Vec<usize>,
        seed_base: u32,
        batch: usize,
    ) -> Result<ModelSpec, ModelError> {
        let spec = ModelSpec { name: name.into(), method, dims, budgets, seed_base, batch };
        spec.validate()?;
        Ok(spec)
    }

    /// Convenience constructor for a hashed embedding-bag spec with
    /// consistent `dims = [num_categories, dim]` / `budgets = [k]`.
    pub fn embedding(
        name: impl Into<String>,
        num_categories: usize,
        dim: usize,
        k: usize,
        mode: BagMode,
        seed_base: u32,
        batch: usize,
    ) -> Result<ModelSpec, ModelError> {
        ModelSpec::new(
            name,
            Method::HashedEmbedding { num_categories, dim, k, mode },
            vec![num_categories, dim],
            vec![k],
            seed_base,
            batch,
        )
    }

    /// The embedding shape `(num_categories, dim, k, mode)` when this
    /// spec is a [`Method::HashedEmbedding`]; `None` otherwise.
    pub fn embedding_shape(&self) -> Option<(usize, usize, usize, BagMode)> {
        match self.method {
            Method::HashedEmbedding { num_categories, dim, k, mode } => {
                Some((num_categories, dim, k, mode))
            }
            _ => None,
        }
    }

    /// Check the structural invariants.
    pub fn validate(&self) -> Result<(), ModelError> {
        if self.name.is_empty() {
            return Err(ModelError::InvalidSpec("empty name".into()));
        }
        if let Method::HashedEmbedding { num_categories, dim, k, .. } = self.method {
            // the variant's shape fields and the generic dims/budgets
            // describe the same table — reject silent disagreement
            if self.dims != [num_categories, dim] {
                return Err(ModelError::InvalidSpec(format!(
                    "embedding dims {:?} must equal [num_categories, dim] = [{num_categories}, {dim}]",
                    self.dims
                )));
            }
            if self.budgets != [k] {
                return Err(ModelError::InvalidSpec(format!(
                    "embedding budgets {:?} must equal [k] = [{k}]",
                    self.budgets
                )));
            }
        }
        if let Method::HashedTile { tile: (th, tw) } = self.method {
            if th == 0 || tw == 0 {
                return Err(ModelError::InvalidSpec(format!("zero tile dim in {th}x{tw}")));
            }
            // every run must fit inside its layer's budget
            if let Some(&b) = self.budgets.iter().find(|&&b| b < th * tw) {
                return Err(ModelError::InvalidSpec(format!(
                    "budget {b} is smaller than the tile area {th}x{tw} = {}",
                    th * tw
                )));
            }
        }
        if self.dims.len() < 2 {
            return Err(ModelError::InvalidSpec(format!(
                "need at least 2 dims (input, output), got {:?}",
                self.dims
            )));
        }
        if self.budgets.len() != self.dims.len() - 1 {
            return Err(ModelError::InvalidSpec(format!(
                "{} dims imply {} layers but {} budgets given",
                self.dims.len(),
                self.dims.len() - 1,
                self.budgets.len()
            )));
        }
        if let Some(d) = self.dims.iter().find(|&&d| d == 0) {
            return Err(ModelError::InvalidSpec(format!("zero dim {d} in {:?}", self.dims)));
        }
        if self.budgets.contains(&0) {
            return Err(ModelError::InvalidSpec(format!("zero budget in {:?}", self.budgets)));
        }
        if self.batch == 0 {
            return Err(ModelError::InvalidSpec("zero batch".into()));
        }
        Ok(())
    }

    /// Layer count.
    pub fn n_layers(&self) -> usize {
        self.dims.len() - 1
    }

    /// Input width.
    pub fn n_in(&self) -> usize {
        self.dims[0]
    }

    /// Output (logit) width.
    pub fn n_out(&self) -> usize {
        *self.dims.last().unwrap()
    }

    /// The per-layer [`LayerKind`]s this spec builds. Empty for
    /// embedding specs: an embedding bag is a lookup table, not a stack
    /// of activation layers, and building a `LayerKind::Hashed` for it
    /// would materialize a per-cell plan over the virtual table.
    pub fn layer_kinds(&self) -> Vec<LayerKind> {
        if matches!(self.method, Method::HashedEmbedding { .. }) {
            return Vec::new();
        }
        (0..self.n_layers())
            .map(|l| self.method.layer_kind(self.dims[l + 1], self.budgets[l]))
            .collect()
    }

    /// Lengths of the parameter tensors in bundle order — the artifact
    /// layout: dense layers contribute `[W (n·m), b (n)]` as two
    /// tensors, every other kind one tensor. An embedding spec stores
    /// exactly one tensor: the bucket array `w` of length `k`.
    pub fn param_layout(&self) -> Vec<usize> {
        if let Some((_, _, k, _)) = self.embedding_shape() {
            return vec![k];
        }
        let mut out = Vec::new();
        for (l, kind) in self.layer_kinds().into_iter().enumerate() {
            let (m, n) = (self.dims[l], self.dims[l + 1]);
            match kind {
                LayerKind::Dense => {
                    out.push(n * m);
                    out.push(n);
                }
                LayerKind::Hashed { k } | LayerKind::HashedTile { k, .. } => out.push(k),
                LayerKind::Masked { .. } => out.push(n * (m + 1)),
                LayerKind::LowRank { r } => out.push(n * r),
            }
        }
        out
    }

    /// Logical stored-parameter count (RER counts kept edges, not the
    /// dense mask buffer — matching `nn::Layer::n_stored`).
    pub fn stored_params(&self) -> usize {
        if let Some((_, _, k, _)) = self.embedding_shape() {
            return k;
        }
        self.layer_kinds()
            .into_iter()
            .enumerate()
            .map(|(l, kind)| {
                let (m, n) = (self.dims[l], self.dims[l + 1]);
                match kind {
                    LayerKind::Dense => n * m + n,
                    LayerKind::Hashed { k }
                    | LayerKind::HashedTile { k, .. }
                    | LayerKind::Masked { k } => k,
                    LayerKind::LowRank { r } => n * r,
                }
            })
            .sum()
    }

    /// Virtual (decompressed) parameter count: `n·(m+1)` per
    /// non-dense layer (bias column folded in), `n·m + n` for dense,
    /// `num_categories · dim` for an embedding table (no bias column —
    /// lookups have no activation input).
    pub fn virtual_params(&self) -> usize {
        if let Some((nc, dim, _, _)) = self.embedding_shape() {
            return nc * dim;
        }
        (0..self.n_layers())
            .map(|l| {
                let (m, n) = (self.dims[l], self.dims[l + 1]);
                n * (m + 1)
            })
            .sum()
    }

    /// Stored / virtual — the compression the spec realizes.
    pub fn compression(&self) -> f64 {
        self.stored_params() as f64 / self.virtual_params() as f64
    }

    // -- JSON round trip -------------------------------------------------

    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("name", Json::Str(self.name.clone())),
            ("method", Json::Str(self.method.as_str().to_string())),
            ("dims", Json::Arr(self.dims.iter().map(|&d| num(d as f64)).collect())),
            (
                "budgets",
                Json::Arr(self.budgets.iter().map(|&b| num(b as f64)).collect()),
            ),
            ("seed_base", num(self.seed_base as f64)),
            ("batch", num(self.batch as f64)),
        ];
        if let Some((_, _, _, mode)) = self.embedding_shape() {
            pairs.push(("mode", Json::Str(mode.as_str().to_string())));
        }
        if let Method::HashedTile { tile: (th, tw) } = self.method {
            pairs.push(("tile", Json::Str(format!("{th}x{tw}"))));
        }
        obj(pairs)
    }

    pub fn from_json(v: &Json) -> Result<ModelSpec, ModelError> {
        let inv = ModelError::InvalidSpec;
        let usize_arr = |key: &str| -> Result<Vec<usize>, ModelError> {
            let arr = v.req_arr(key).map_err(inv)?;
            let vals: Vec<usize> = arr.iter().filter_map(Json::as_usize).collect();
            if vals.len() != arr.len() {
                return Err(ModelError::InvalidSpec(format!("non-integer entry in '{key}'")));
            }
            Ok(vals)
        };
        let method_str = v.req_str("method").map_err(inv)?;
        let dims = usize_arr("dims")?;
        let budgets = usize_arr("budgets")?;
        let method = if method_str == "hashed_embedding" {
            // the variant's shape fields derive from dims/budgets; the
            // optional "mode" key defaults to sum
            if dims.len() != 2 || budgets.len() != 1 {
                return Err(ModelError::InvalidSpec(format!(
                    "hashed_embedding needs dims=[num_categories, dim], budgets=[k]; got dims {dims:?}, budgets {budgets:?}"
                )));
            }
            let mode = match v.get("mode") {
                Some(m) => BagMode::parse(
                    m.as_str().ok_or_else(|| ModelError::InvalidSpec("'mode' must be a string".into()))?,
                )?,
                None => BagMode::Sum,
            };
            Method::HashedEmbedding { num_categories: dims[0], dim: dims[1], k: budgets[0], mode }
        } else if method_str == "hashed_tile" {
            // the tile shape changes the weight mapping entirely, so —
            // unlike the embedding's defaultable "mode" — it is required
            let tile_str = v
                .get("tile")
                .and_then(Json::as_str)
                .ok_or_else(|| {
                    ModelError::InvalidSpec(
                        "hashed_tile needs a string 'tile' key (e.g. \"8x8\")".into(),
                    )
                })?;
            Method::HashedTile { tile: Method::parse_tile(tile_str)? }
        } else {
            Method::parse(method_str)?
        };
        ModelSpec::new(
            v.req_str("name").map_err(inv)?.to_string(),
            method,
            dims,
            budgets,
            v.req_f64("seed_base").map_err(inv)? as u32,
            v.req_f64("batch").map_err(inv)? as usize,
        )
    }

    pub fn to_json_string(&self) -> String {
        self.to_json().to_string()
    }

    pub fn from_json_str(text: &str) -> Result<ModelSpec, ModelError> {
        let v = Json::parse(text).map_err(ModelError::InvalidSpec)?;
        ModelSpec::from_json(&v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> ModelSpec {
        ModelSpec::new("t", Method::Hashnet, vec![8, 6, 3], vec![27, 11], 0x9E37_79B9, 4)
            .unwrap()
    }

    #[test]
    fn parse_roundtrip_every_method() {
        for m in Method::ALL {
            assert_eq!(Method::parse(m.as_str()).unwrap(), m);
        }
        assert!(matches!(
            Method::parse("convnet"),
            Err(ModelError::UnknownMethod(s)) if s == "convnet"
        ));
    }

    #[test]
    fn soft_target_methods() {
        assert!(Method::Dk.uses_soft_targets());
        assert!(Method::HashnetDk.uses_soft_targets());
        assert!(!Method::Hashnet.uses_soft_targets());
        assert!(!Method::Nn.uses_soft_targets());
    }

    #[test]
    fn json_roundtrip() {
        let s = spec();
        let back = ModelSpec::from_json_str(&s.to_json_string()).unwrap();
        assert_eq!(s, back);
    }

    #[test]
    fn validation_rejects_bad_specs() {
        assert!(ModelSpec::new("t", Method::Nn, vec![8], vec![], 1, 4).is_err());
        assert!(ModelSpec::new("t", Method::Nn, vec![8, 3], vec![1, 2], 1, 4).is_err());
        assert!(ModelSpec::new("t", Method::Nn, vec![8, 0, 3], vec![1, 2], 1, 4).is_err());
        assert!(ModelSpec::new("t", Method::Hashnet, vec![8, 3], vec![0], 1, 4).is_err());
        assert!(ModelSpec::new("", Method::Nn, vec![8, 3], vec![9], 1, 4).is_err());
        assert!(ModelSpec::new("t", Method::Nn, vec![8, 3], vec![9], 1, 0).is_err());
    }

    #[test]
    fn layouts_and_accounting() {
        let s = spec();
        assert_eq!(s.param_layout(), vec![27, 11]);
        assert_eq!(s.stored_params(), 38);
        assert_eq!(s.virtual_params(), 6 * 9 + 3 * 7);
        let d = ModelSpec::new("d", Method::Nn, vec![8, 6, 3], vec![54, 21], 1, 4).unwrap();
        assert_eq!(d.param_layout(), vec![48, 6, 18, 3]);
        assert_eq!(d.stored_params(), 75);
        let r = ModelSpec::new("r", Method::Rer, vec![8, 6, 3], vec![27, 11], 1, 4).unwrap();
        assert_eq!(r.param_layout(), vec![54, 21]); // physical mask buffers
        assert_eq!(r.stored_params(), 38); // logical kept edges
        let l = ModelSpec::new("l", Method::Lrd, vec![8, 6, 3], vec![12, 6], 1, 4).unwrap();
        // r = round(12/6) = 2 → 6*2 = 12; r = round(6/3) = 2 → 3*2 = 6
        assert_eq!(l.param_layout(), vec![12, 6]);
    }

    #[test]
    fn embedding_spec_roundtrip_and_accounting() {
        let e = ModelSpec::embedding("emb", 1_000_000, 64, 8_000_000, BagMode::Mean, 7, 32)
            .unwrap();
        assert_eq!(e.param_layout(), vec![8_000_000]);
        assert_eq!(e.stored_params(), 8_000_000);
        assert_eq!(e.virtual_params(), 64_000_000);
        assert!((e.compression() - 0.125).abs() < 1e-9);
        assert!(e.layer_kinds().is_empty());
        assert_eq!(e.embedding_shape(), Some((1_000_000, 64, 8_000_000, BagMode::Mean)));
        let back = ModelSpec::from_json_str(&e.to_json_string()).unwrap();
        assert_eq!(back, e);
        assert!(back.to_json_string().contains("\"mode\":\"mean\""));
        // "mode" omitted → sum
        let no_mode = r#"{"name":"e","method":"hashed_embedding","dims":[100,8],"budgets":[25],"seed_base":1,"batch":4}"#;
        let s = ModelSpec::from_json_str(no_mode).unwrap();
        assert_eq!(s.embedding_shape(), Some((100, 8, 25, BagMode::Sum)));
    }

    #[test]
    fn embedding_spec_rejects_inconsistent_shapes() {
        // variant fields must agree with dims/budgets
        let m = Method::HashedEmbedding { num_categories: 10, dim: 4, k: 5, mode: BagMode::Sum };
        assert!(ModelSpec::new("e", m, vec![10, 5], vec![5], 1, 4).is_err());
        assert!(ModelSpec::new("e", m, vec![10, 4], vec![6], 1, 4).is_err());
        assert!(ModelSpec::new("e", m, vec![10, 4], vec![5], 1, 4).is_ok());
        // three dims can't be an embedding table
        let bad = r#"{"name":"e","method":"hashed_embedding","dims":[10,4,2],"budgets":[5,3],"seed_base":1,"batch":4}"#;
        assert!(ModelSpec::from_json_str(bad).is_err());
        let bad_mode = r#"{"name":"e","method":"hashed_embedding","dims":[10,4],"budgets":[5],"seed_base":1,"batch":4,"mode":"max"}"#;
        assert!(ModelSpec::from_json_str(bad_mode).is_err());
    }

    #[test]
    fn tile_spec_roundtrip_and_accounting() {
        let t = ModelSpec::new(
            "tile",
            Method::HashedTile { tile: (8, 8) },
            vec![8, 6, 3],
            vec![80, 70],
            0x9E37_79B9,
            4,
        )
        .unwrap();
        assert_eq!(t.param_layout(), vec![80, 70]);
        assert_eq!(t.stored_params(), 150);
        assert_eq!(t.virtual_params(), 6 * 9 + 3 * 7);
        let back = ModelSpec::from_json_str(&t.to_json_string()).unwrap();
        assert_eq!(back, t);
        assert!(t.to_json_string().contains("\"tile\":\"8x8\""));
        assert_eq!(
            t.layer_kinds(),
            vec![
                LayerKind::HashedTile { k: 80, tile: (8, 8) },
                LayerKind::HashedTile { k: 70, tile: (8, 8) },
            ]
        );
    }

    #[test]
    fn tile_spec_validation_and_parsing() {
        // budget below tile area
        assert!(ModelSpec::new(
            "t",
            Method::HashedTile { tile: (8, 8) },
            vec![8, 6, 3],
            vec![80, 63],
            1,
            4
        )
        .is_err());
        // zero tile dim
        assert!(ModelSpec::new(
            "t",
            Method::HashedTile { tile: (0, 8) },
            vec![8, 6, 3],
            vec![80, 70],
            1,
            4
        )
        .is_err());
        // tile key is required in JSON
        let no_tile = r#"{"name":"t","method":"hashed_tile","dims":[8,3],"budgets":[70],"seed_base":1,"batch":4}"#;
        assert!(ModelSpec::from_json_str(no_tile).is_err());
        // tile-string parser
        assert_eq!(Method::parse_tile("1x8").unwrap(), (1, 8));
        assert_eq!(Method::parse_tile("8x8").unwrap(), (8, 8));
        assert!(Method::parse_tile("8").is_err());
        assert!(Method::parse_tile("0x8").is_err());
        assert!(Method::parse_tile("axb").is_err());
        // bare name is not parseable (needs the tile field)
        assert!(matches!(
            Method::parse("hashed_tile"),
            Err(ModelError::UnknownMethod(_))
        ));
    }

    #[test]
    fn from_json_rejects_unknown_method_and_bad_arrays() {
        let bad_method = r#"{"name":"x","method":"blob","dims":[4,2],"budgets":[3],"seed_base":1,"batch":2}"#;
        assert!(matches!(
            ModelSpec::from_json_str(bad_method),
            Err(ModelError::UnknownMethod(_))
        ));
        let bad_dim = r#"{"name":"x","method":"nn","dims":[4,"two"],"budgets":[3],"seed_base":1,"batch":2}"#;
        assert!(matches!(
            ModelSpec::from_json_str(bad_dim),
            Err(ModelError::InvalidSpec(_))
        ));
    }
}
