//! Zero-copy bundle loading: [`BundleMap`] (an mmap'd, fully validated
//! HNMB file) and [`ParamStore`] (a parameter buffer that is either an
//! owned `Vec<f32>` or a borrow into a mapped bundle).
//!
//! The paper's deployment story is "a fleet of tiny models": a
//! HashedNet is `(dims, K, seed)` plus K bucket values, so one serve
//! process should hold hundreds of them. The v1 load path
//! (`read → parse → copy`) pays for each model twice — once in the page
//! cache and once on the heap. A v2 bundle's payloads are 64-byte
//! aligned ([`super::bundle::SECTION_ALIGN`]), so an f32 tensor can be
//! served *in place* from the mapping:
//!
//! * [`BundleMap::open`] maps the file (`mmap(2)`, `PROT_READ` +
//!   `MAP_PRIVATE`; heap fallback when mmap is unavailable) and runs
//!   the full [`super::bundle::parse`] validation — magic, version,
//!   section table, alignment, checksum, spec — so a mapped bundle is
//!   exactly as trusted as a loaded one.
//! * [`BundleMap::tensor_f32`] borrows an f32 section as `&[f32]`
//!   without copying (little-endian hosts only; quantized sections
//!   dequantize through [`BundleMap::tensor_dequant`] instead).
//! * [`ParamStore`] lets `nn::Layer::params` / `nn::EmbedBag::w` hold
//!   either form behind one `Deref<Target = [f32]>`. The mapped variant
//!   caches the raw slice pointer at construction, so the serve-path
//!   kernels (`w[b]` per virtual cell) pay nothing over a `Vec`.
//!   Mutation (`DerefMut`) copies on write — training a mapped model
//!   silently promotes its tensors to owned memory.
//!
//! Safety: the mapped pointer is valid for the lifetime of the
//! `Arc<BundleMap>` each `ParamStore` clones, the mapping is read-only
//! and private, and [`super::bundle::parse`] bounds every section
//! against the real file length before any slice is formed. Truncating
//! the file *while mapped* is outside the contract (SIGBUS, as with any
//! mmap consumer); the serve hot-swap path never rewrites a bundle in
//! place — `ModelBundle::save` renames a fresh inode into the name.

use super::bundle::{self, RawSection};
use super::quant::CODEC_F32;
use super::{ModelError, ModelSpec};
use crate::nn::{EmbedBag, LayerKind, Network};
use std::fmt;
use std::ops::{Deref, DerefMut};
use std::path::Path;
use std::sync::Arc;

// ---------------------------------------------------------------------------
// raw mmap surface (same no-new-crates idiom as serve/poll.rs)
// ---------------------------------------------------------------------------

#[cfg(unix)]
mod sys {
    use std::ffi::c_void;

    pub const PROT_READ: i32 = 1;
    pub const MAP_PRIVATE: i32 = 2;

    extern "C" {
        fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: i32,
            flags: i32,
            fd: i32,
            offset: i64,
        ) -> *mut c_void;
        fn munmap(addr: *mut c_void, len: usize) -> i32;
    }

    /// Map `len` bytes of `file` read-only. `None` on failure (caller
    /// falls back to a heap copy).
    pub fn map_file(file: &std::fs::File, len: usize) -> Option<*const u8> {
        use std::os::unix::io::AsRawFd;
        let failed = usize::MAX as *mut c_void; // MAP_FAILED
        let ptr = unsafe {
            mmap(std::ptr::null_mut(), len, PROT_READ, MAP_PRIVATE, file.as_raw_fd(), 0)
        };
        if ptr == failed || ptr.is_null() {
            None
        } else {
            Some(ptr as *const u8)
        }
    }

    pub fn unmap(ptr: *const u8, len: usize) {
        unsafe {
            munmap(ptr as *mut c_void, len);
        }
    }
}

/// The backing bytes: a real mapping, or a heap copy (mmap failure,
/// non-unix hosts). The heap copy lives in a `Vec<u64>` so its base is
/// 8-byte aligned — together with page-aligned mmap bases, every
/// backing starts at least 4-byte aligned and the per-section check in
/// [`BundleMap::tensor_f32`] only has to look at the offset.
enum MapBuf {
    #[cfg(unix)]
    Mmap { ptr: *const u8, map_len: usize },
    Heap(Vec<u64>),
}

impl Drop for MapBuf {
    fn drop(&mut self) {
        #[cfg(unix)]
        if let MapBuf::Mmap { ptr, map_len } = *self {
            sys::unmap(ptr, map_len);
        }
    }
}

/// An open, validated, memory-mapped model bundle. See the module docs.
pub struct BundleMap {
    buf: MapBuf,
    len: usize,
    spec: ModelSpec,
    version: u32,
    sections: Vec<RawSection>,
}

// The mapping is read-only, private, and owned by this struct for its
// whole lifetime — sharing &BundleMap (or the struct itself) across
// threads is sound.
unsafe impl Send for BundleMap {}
unsafe impl Sync for BundleMap {}

impl BundleMap {
    /// Map `path` and run the full bundle validation (structure,
    /// checksum, spec). Accepts both v1 and v2 files; only v2 sections
    /// can be borrowed in place (v1 tensor offsets are generally
    /// unaligned).
    pub fn open(path: &Path) -> Result<BundleMap, ModelError> {
        let file = std::fs::File::open(path)?;
        let len = file.metadata()?.len() as usize;
        let buf =
            if len == 0 { MapBuf::Heap(Vec::new()) } else { map_or_copy(&file, path, len)? };
        let raw = bundle::parse(view(&buf, len))?;
        Ok(BundleMap { buf, len, spec: raw.spec, version: raw.version, sections: raw.sections })
    }

    /// The whole file, checksum included.
    pub fn bytes(&self) -> &[u8] {
        view(&self.buf, self.len)
    }

    pub fn spec(&self) -> &ModelSpec {
        &self.spec
    }

    pub fn version(&self) -> u32 {
        self.version
    }

    pub fn n_tensors(&self) -> usize {
        self.sections.len()
    }

    /// Total file size — what `1..200` resident mapped models actually
    /// cost (shared, page-cache-backed) versus heap copies.
    pub fn file_bytes(&self) -> usize {
        self.len
    }

    /// Decoded element count of tensor `index`.
    pub fn tensor_len(&self, index: usize) -> Option<usize> {
        self.sections.get(index).map(|s| s.n_elems)
    }

    /// `true` while the backing is a real mapping (a heap fallback
    /// still works, it just isn't zero-copy).
    pub fn is_mmap(&self) -> bool {
        match self.buf {
            #[cfg(unix)]
            MapBuf::Mmap { .. } => true,
            MapBuf::Heap(_) => false,
        }
    }

    /// Borrow tensor `index` in place as `&[f32]`. `None` when the
    /// section is quantized, its payload is not 4-byte aligned in
    /// memory (possible for v1 files), or the host is big-endian (the
    /// payload is little-endian on disk).
    pub fn tensor_f32(&self, index: usize) -> Option<&[f32]> {
        if cfg!(target_endian = "big") {
            return None;
        }
        let s = self.sections.get(index)?;
        if s.codec != CODEC_F32 {
            return None;
        }
        let bytes = self.bytes();
        let p = bytes[s.offset..s.offset + s.enc_len].as_ptr();
        if (p as usize) % std::mem::align_of::<f32>() != 0 {
            return None;
        }
        Some(unsafe { std::slice::from_raw_parts(p as *const f32, s.n_elems) })
    }

    /// Decode tensor `index` onto the heap (works for every codec and
    /// alignment — the training / quantized path).
    pub fn tensor_dequant(&self, index: usize) -> Option<Vec<f32>> {
        let s = self.sections.get(index)?;
        Some(bundle::decode_section(self.bytes(), s).0)
    }

    /// Decode everything into an owned [`super::ModelBundle`]
    /// (shape-checked) — the bridge back to the copying world.
    pub fn to_bundle(&self) -> Result<super::ModelBundle, ModelError> {
        let bytes = self.bytes();
        let mut params = Vec::with_capacity(self.sections.len());
        let mut encodings = Vec::with_capacity(self.sections.len());
        for s in &self.sections {
            let (p, e) = bundle::decode_section(bytes, s);
            params.push(p);
            encodings.push(e);
        }
        let b = super::ModelBundle {
            spec: self.spec.clone(),
            params,
            encodings,
            version: self.version,
        };
        b.check_shapes()?;
        Ok(b)
    }

    fn check_layout(&self) -> Result<(), ModelError> {
        let expect = self.spec.param_layout();
        let got: Vec<usize> = self.sections.iter().map(|s| s.n_elems).collect();
        if got != expect {
            return Err(ModelError::ShapeMismatch(format!(
                "model '{}' ({}, dims {:?}) expects tensor lengths {:?}, got {:?}",
                self.spec.name, self.spec.method, self.spec.dims, expect, got
            )));
        }
        Ok(())
    }
}

impl fmt::Debug for BundleMap {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("BundleMap")
            .field("spec", &self.spec.name)
            .field("version", &self.version)
            .field("file_bytes", &self.len)
            .field("n_tensors", &self.sections.len())
            .field("mmap", &self.is_mmap())
            .finish()
    }
}

fn heap_copy(bytes: &[u8]) -> MapBuf {
    let mut words = vec![0u64; bytes.len().div_ceil(8)];
    unsafe {
        std::ptr::copy_nonoverlapping(bytes.as_ptr(), words.as_mut_ptr() as *mut u8, bytes.len());
    }
    MapBuf::Heap(words)
}

#[cfg(unix)]
fn map_or_copy(file: &std::fs::File, path: &Path, len: usize) -> Result<MapBuf, ModelError> {
    if let Some(ptr) = sys::map_file(file, len) {
        return Ok(MapBuf::Mmap { ptr, map_len: len });
    }
    Ok(heap_copy(&std::fs::read(path)?))
}

#[cfg(not(unix))]
fn map_or_copy(_file: &std::fs::File, path: &Path, _len: usize) -> Result<MapBuf, ModelError> {
    Ok(heap_copy(&std::fs::read(path)?))
}

fn view(buf: &MapBuf, len: usize) -> &[u8] {
    match buf {
        #[cfg(unix)]
        MapBuf::Mmap { ptr, .. } => unsafe { std::slice::from_raw_parts(*ptr, len) },
        MapBuf::Heap(v) => unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, len) },
    }
}

// ---------------------------------------------------------------------------
// ParamStore
// ---------------------------------------------------------------------------

/// A parameter buffer: owned floats, or a zero-copy borrow into a
/// mapped bundle. Derefs to `[f32]` either way; writing through
/// `DerefMut` promotes a mapped buffer to an owned copy first
/// (copy-on-write), so training code is oblivious to the distinction.
pub struct ParamStore(Repr);

enum Repr {
    Owned(Vec<f32>),
    /// `ptr`/`len` are the resolved f32 section inside `map`, cached at
    /// construction so `Deref` costs a match + pointer read — the serve
    /// kernels index `w[b]` per virtual cell and must not pay a section
    /// lookup each time. `map` is held only to keep the bytes alive.
    Mapped { map: Arc<BundleMap>, ptr: *const f32, len: usize },
}

// Mapped memory is read-only and pinned by the Arc; see BundleMap.
unsafe impl Send for ParamStore {}
unsafe impl Sync for ParamStore {}

impl ParamStore {
    /// Borrow tensor `index` of `map` in place. `None` when the tensor
    /// cannot be borrowed (quantized, misaligned, big-endian host) —
    /// callers fall back to [`BundleMap::tensor_dequant`].
    pub fn mapped(map: &Arc<BundleMap>, index: usize) -> Option<ParamStore> {
        let s = map.tensor_f32(index)?;
        let (ptr, len) = (s.as_ptr(), s.len());
        Some(ParamStore(Repr::Mapped { map: Arc::clone(map), ptr, len }))
    }

    /// `true` while the buffer still borrows the mapped file (becomes
    /// `false` after any write). Resident-memory accounting in the load
    /// bench keys off this.
    pub fn is_mapped(&self) -> bool {
        matches!(self.0, Repr::Mapped { .. })
    }
}

impl Deref for ParamStore {
    type Target = [f32];
    #[inline]
    fn deref(&self) -> &[f32] {
        match &self.0 {
            Repr::Owned(v) => v,
            Repr::Mapped { ptr, len, .. } => unsafe { std::slice::from_raw_parts(*ptr, *len) },
        }
    }
}

impl DerefMut for ParamStore {
    #[inline]
    fn deref_mut(&mut self) -> &mut [f32] {
        if self.is_mapped() {
            // copy-on-write: the mapping is PROT_READ, so mutation
            // means this model now owns (this tensor of) its weights
            self.0 = Repr::Owned(self[..].to_vec());
        }
        match &mut self.0 {
            Repr::Owned(v) => v,
            Repr::Mapped { .. } => unreachable!("promoted above"),
        }
    }
}

impl Clone for ParamStore {
    fn clone(&self) -> ParamStore {
        match &self.0 {
            Repr::Owned(v) => ParamStore(Repr::Owned(v.clone())),
            Repr::Mapped { map, ptr, len } => {
                ParamStore(Repr::Mapped { map: Arc::clone(map), ptr: *ptr, len: *len })
            }
        }
    }
}

impl fmt::Debug for ParamStore {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.is_mapped() {
            write!(f, "mapped:")?;
        }
        write!(f, "{:?}", &self[..])
    }
}

impl PartialEq for ParamStore {
    fn eq(&self, other: &ParamStore) -> bool {
        self[..] == other[..]
    }
}

impl PartialEq<Vec<f32>> for ParamStore {
    fn eq(&self, other: &Vec<f32>) -> bool {
        self[..] == other[..]
    }
}

impl PartialEq<ParamStore> for Vec<f32> {
    fn eq(&self, other: &ParamStore) -> bool {
        self[..] == other[..]
    }
}

impl From<Vec<f32>> for ParamStore {
    fn from(v: Vec<f32>) -> ParamStore {
        ParamStore(Repr::Owned(v))
    }
}

impl Default for ParamStore {
    fn default() -> ParamStore {
        ParamStore(Repr::Owned(Vec::new()))
    }
}

// ---------------------------------------------------------------------------
// zero-copy model construction
// ---------------------------------------------------------------------------

impl Network {
    /// Build a network over a mapped bundle without copying its f32
    /// tensors: single-tensor layers (hashed / masked / low-rank — the
    /// layers the paper's compression produces) borrow the mapping in
    /// place; dense layers (whose `[W, b]` pair must be one contiguous
    /// buffer) and quantized tensors decode onto the heap.
    pub fn from_bundle_map(map: &Arc<BundleMap>) -> Result<Network, ModelError> {
        map.check_layout()?;
        let mut net = Network::from_spec(map.spec())?;
        let mut ti = 0usize;
        for layer in &mut net.layers {
            match layer.kind {
                LayerKind::Dense => {
                    let w = map.tensor_dequant(ti).expect("layout checked");
                    let b = map.tensor_dequant(ti + 1).expect("layout checked");
                    ti += 2;
                    layer.params[..w.len()].copy_from_slice(&w);
                    layer.params[w.len()..].copy_from_slice(&b);
                }
                _ => {
                    layer.params = match ParamStore::mapped(map, ti) {
                        Some(ps) => ps,
                        None => map.tensor_dequant(ti).expect("layout checked").into(),
                    };
                    ti += 1;
                }
            }
        }
        Ok(net)
    }
}

impl EmbedBag {
    /// Build an embedding bag over a mapped bundle: the single bucket
    /// tensor is borrowed in place when it is f32, decoded when
    /// quantized.
    pub fn from_bundle_map(map: &Arc<BundleMap>) -> Result<EmbedBag, ModelError> {
        map.check_layout()?;
        let w = match ParamStore::mapped(map, 0) {
            Some(ps) => ps,
            None => map.tensor_dequant(0).expect("layout checked").into(),
        };
        EmbedBag::from_store(map.spec(), w)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{BagMode, Method, ModelBundle, QuantSpec};
    use crate::util::rng::Pcg32;

    struct TempFile(std::path::PathBuf);
    impl TempFile {
        fn new(tag: &str) -> TempFile {
            TempFile(std::env::temp_dir().join(format!(
                "hn_map_{tag}_{}_{:?}.hnmb",
                std::process::id(),
                std::thread::current().id()
            )))
        }
    }
    impl Drop for TempFile {
        fn drop(&mut self) {
            let _ = std::fs::remove_file(&self.0);
        }
    }

    fn hashnet_bundle() -> (ModelSpec, Network, ModelBundle) {
        let spec =
            ModelSpec::new("unit", Method::Hashnet, vec![6, 5, 3], vec![14, 7], 0x9E37_79B9, 4)
                .unwrap();
        let mut net = Network::from_spec(&spec).unwrap();
        net.init(&mut Pcg32::new(5, 5));
        let bundle = net.to_bundle(&spec).unwrap();
        (spec, net, bundle)
    }

    #[test]
    fn mapped_network_predicts_bit_equal_and_borrows_in_place() {
        let (_, net, bundle) = hashnet_bundle();
        let tmp = TempFile::new("net");
        bundle.save(&tmp.0).unwrap();
        let map = Arc::new(BundleMap::open(&tmp.0).unwrap());
        assert_eq!(map.version(), crate::model::BUNDLE_VERSION);
        let served = Network::from_bundle_map(&map).unwrap();
        for (a, b) in served.layers.iter().zip(&net.layers) {
            assert_eq!(a.params, b.params);
        }
        // hashed layers borrow the file; nothing was copied
        if map.is_mmap() {
            assert!(served.layers.iter().all(|l| l.params.is_mapped()));
        }
        let x = crate::tensor::Matrix::from_fn(3, 6, |i, j| (i * 6 + j) as f32 * 0.1);
        assert_eq!(served.predict(&x).data, net.predict(&x).data);
    }

    #[test]
    fn quantized_sections_dequantize_not_borrow() {
        let (_, _, bundle) = hashnet_bundle();
        let qb = bundle.quantize(QuantSpec::Int8).unwrap();
        let tmp = TempFile::new("quant");
        qb.save(&tmp.0).unwrap();
        let map = Arc::new(BundleMap::open(&tmp.0).unwrap());
        assert!(map.tensor_f32(0).is_none(), "int8 sections cannot be borrowed as f32");
        assert_eq!(map.tensor_dequant(0).unwrap(), qb.params[0]);
        let served = Network::from_bundle_map(&map).unwrap();
        assert!(served.layers.iter().all(|l| !l.params.is_mapped()));
        assert_eq!(served.layers[0].params, qb.params[0]);
        // and the owned bridge reproduces the bundle exactly
        let back = map.to_bundle().unwrap();
        assert_eq!(back.params, qb.params);
        assert_eq!(back.encodings, qb.encodings);
    }

    #[test]
    fn v1_files_open_and_convert() {
        let (_, _, bundle) = hashnet_bundle();
        let tmp = TempFile::new("v1");
        std::fs::write(&tmp.0, bundle.to_bytes_v1().unwrap()).unwrap();
        let map = Arc::new(BundleMap::open(&tmp.0).unwrap());
        assert_eq!(map.version(), 1);
        assert_eq!(map.to_bundle().unwrap().params, bundle.params);
        // v1 loads still work through the map path (owned or borrowed,
        // depending on accidental alignment)
        let served = Network::from_bundle_map(&map).unwrap();
        assert_eq!(served.layers[0].params, bundle.params[0]);
    }

    #[test]
    fn mapped_embed_bag_serves_in_place() {
        let spec =
            ModelSpec::embedding("bag", 1_000, 8, 64, BagMode::Mean, 0x9E37_79B9, 4).unwrap();
        let mut bag = EmbedBag::new(1_000, 8, 64, BagMode::Mean, 0x9E37_79B9);
        bag.init(&mut Pcg32::new(3, 3));
        let tmp = TempFile::new("bag");
        bag.to_bundle(&spec).unwrap().save(&tmp.0).unwrap();
        let map = Arc::new(BundleMap::open(&tmp.0).unwrap());
        let served = EmbedBag::from_bundle_map(&map).unwrap();
        assert_eq!(served.w, bag.w);
        if map.is_mmap() {
            assert!(served.w.is_mapped());
        }
        let (indices, offsets) = (vec![1u32, 7, 423, 999], vec![0u32, 2]);
        assert_eq!(
            served.forward(&indices, &offsets).data,
            bag.forward(&indices, &offsets).data
        );
    }

    #[test]
    fn copy_on_write_promotes_to_owned() {
        let (_, _, bundle) = hashnet_bundle();
        let tmp = TempFile::new("cow");
        bundle.save(&tmp.0).unwrap();
        let map = Arc::new(BundleMap::open(&tmp.0).unwrap());
        let mut served = Network::from_bundle_map(&map).unwrap();
        let before = served.layers[0].params[0];
        served.layers[0].params[0] = before + 1.0;
        assert!(!served.layers[0].params.is_mapped(), "write must promote");
        assert_eq!(served.layers[0].params[0], before + 1.0);
        // the file itself is untouched
        assert_eq!(BundleMap::open(&tmp.0).unwrap().tensor_dequant(0).unwrap()[0], before);
    }

    #[test]
    fn open_rejects_what_from_bytes_rejects() {
        let (_, _, bundle) = hashnet_bundle();
        let tmp = TempFile::new("rej");
        let mut bytes = bundle.to_bytes();
        let last = bytes.len() - 1;
        bytes[last] ^= 0xFF;
        std::fs::write(&tmp.0, &bytes).unwrap();
        assert!(matches!(
            BundleMap::open(&tmp.0),
            Err(ModelError::BadChecksum { .. })
        ));
        std::fs::write(&tmp.0, b"").unwrap();
        assert!(matches!(BundleMap::open(&tmp.0), Err(ModelError::Truncated(_))));
    }
}
