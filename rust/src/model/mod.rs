//! The model subsystem: **one** way to name, build, save and load a
//! model, end to end.
//!
//! The paper's central observation is that a HashedNet is
//! reconstructible from almost nothing: `(dims, K, seed)` pins the hash
//! mapping, so the bucket values are the *entire* model. Deep
//! Compression (Han et al., 2015) makes the matching systems argument —
//! the deployable storage format is a first-class deliverable of a
//! compression method — and this module is that deliverable:
//!
//! * [`Method`] — the typed model family (`hashnet`, `hashnet_dk`,
//!   `nn`, `dk`, `rer`, `lrd`), replacing stringly-typed matches with a
//!   fallible [`Method::parse`].
//! * [`ModelSpec`] — the self-describing identity of one model:
//!   method + virtual dims + per-layer budgets + seed. Validated on
//!   construction, JSON round-trippable, and sufficient to rebuild the
//!   network skeleton anywhere ([`crate::nn::Network::from_spec`]).
//! * [`ModelBundle`] — the versioned single-file artifact: a header,
//!   the spec as JSON, the parameter tensors, and a checksum. This is
//!   what `train` saves, what `serve` loads (including hot-loading into
//!   a running server via `{"cmd":"load"}`), and what `compress`
//!   produces from a dense network. Format v2 adds per-tensor
//!   quantization ([`quant`]: int8, k-means codebook) and a 64-byte
//!   aligned section table.
//! * [`BundleMap`] — the zero-copy load path: an mmap'd, validated
//!   bundle whose f32 tensors serve in place ([`ParamStore`] borrows
//!   them without copying); quantized tensors dequantize on load.
//! * [`ModelError`] — typed failures: unknown method, invalid spec,
//!   truncation, checksum mismatch, future format version, parameter
//!   shape mismatch.
//!
//! Everything above this module — trainer, compressor, server, CLI —
//! speaks `ModelSpec`/`ModelBundle`. The legacy pair
//! (`runtime::Manifest`'s `ArtifactSpec` + `runtime::ModelState`
//! checkpoints) survives only as compat shims that convert into these
//! types (`ArtifactSpec::to_model_spec`, `ModelState::to_bundle`).

pub mod bundle;
pub mod map;
pub mod quant;
pub mod spec;

pub use bundle::{ModelBundle, BUNDLE_VERSION, SECTION_ALIGN};
pub use map::{BundleMap, ParamStore};
pub use quant::{Encoding, QuantSpec};
pub use spec::{BagMode, Method, ModelSpec};

use std::fmt;

/// Typed failure modes of the model lifecycle: spec validation, bundle
/// (de)serialization, and network (re)construction.
#[derive(Debug)]
pub enum ModelError {
    /// Underlying file I/O failed.
    Io(std::io::Error),
    /// A method string matched none of [`Method`]'s variants.
    UnknownMethod(String),
    /// A spec failed validation (empty dims, budget/dims mismatch, …).
    InvalidSpec(String),
    /// The file does not start with the bundle magic.
    BadMagic,
    /// The bundle was written by a newer format version than this
    /// binary supports.
    FutureVersion { found: u32, supported: u32 },
    /// The file ends before the structure it declares.
    Truncated(&'static str),
    /// The stored checksum does not match the recomputed one.
    BadChecksum { stored: u32, computed: u32 },
    /// A v2 section-table entry is structurally invalid: unknown codec
    /// tag, non-canonical/misaligned offset, inconsistent encoded
    /// length, or an out-of-range codebook index.
    BadSection(String),
    /// Parameter tensors do not match the spec's layer layout.
    ShapeMismatch(String),
}

impl fmt::Display for ModelError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ModelError::Io(e) => write!(f, "model i/o: {e}"),
            ModelError::UnknownMethod(m) => write!(
                f,
                "unknown method '{m}' (expected one of hashnet, hashnet_dk, nn, dk, rer, lrd, hashed_embedding, hashed_tile)"
            ),
            ModelError::InvalidSpec(why) => write!(f, "invalid model spec: {why}"),
            ModelError::BadMagic => write!(f, "not a model bundle (bad magic)"),
            ModelError::FutureVersion { found, supported } => write!(
                f,
                "bundle format version {found} is newer than supported version {supported}"
            ),
            ModelError::Truncated(what) => write!(f, "truncated bundle: {what}"),
            ModelError::BadChecksum { stored, computed } => write!(
                f,
                "bundle checksum mismatch (stored {stored:#010x}, computed {computed:#010x}) — file corrupt"
            ),
            ModelError::BadSection(why) => write!(f, "invalid bundle section: {why}"),
            ModelError::ShapeMismatch(why) => write!(f, "parameter shape mismatch: {why}"),
        }
    }
}

impl std::error::Error for ModelError {}

impl From<std::io::Error> for ModelError {
    fn from(e: std::io::Error) -> ModelError {
        ModelError::Io(e)
    }
}
