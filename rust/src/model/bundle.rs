//! [`ModelBundle`]: the versioned single-file model artifact, and the
//! [`Network`] construction/persistence glue
//! ([`Network::from_spec`] / [`Network::from_bundle`] /
//! [`Network::to_bundle`]).
//!
//! ## File format
//!
//! Version 2 (current writer) is section-tabled, alignment-padded and
//! per-tensor quantizable, so the payload can be `mmap`ed and served
//! in place (see [`crate::model::map::BundleMap`]):
//!
//! ```text
//! magic     4 B   "HNMB"
//! version   4 B   u32 LE (2)
//! spec_len  4 B   u32 LE
//! spec      …     ModelSpec as UTF-8 JSON (deterministic key order)
//! n_tens    4 B   u32 LE
//! sections  …     n_tens × 16 B: codec u32 | n_elems u32 |
//!                 offset u32 (absolute, 64-byte aligned) | enc_len u32
//! payloads  …     zero-padded to each section's offset; per codec:
//!                   f32 (0):      n_elems × f32 LE
//!                   int8 (1):     min f32 | scale f32 | n_elems × u8
//!                   codebook (2): table_len u32 | table_len × f32 |
//!                                 n_elems × u8
//! checksum  4 B   u32 LE — xxh32 over every preceding byte
//! ```
//!
//! Version 1 (still read, written by [`ModelBundle::to_bytes_v1`] for
//! compat tooling) is the original dense layout: `n_tens`, then per
//! tensor `u32 LE length + length × f32 LE`, same trailing checksum.
//! Checksum coverage is unchanged across versions: every byte before
//! the trailing word, same seed.
//!
//! The reader enforces *canonical packing* for v2: section `i`'s offset
//! must equal the previous payload's end rounded up to
//! [`SECTION_ALIGN`]. A file with reordered, overlapping or misaligned
//! sections is rejected with [`ModelError::BadSection`] — there is
//! exactly one valid byte serialization per bundle, which is what makes
//! `save → load → save` byte-exact and keeps the mmap'd borrow path
//! honest about alignment.
//!
//! Tensors use the artifact layout ([`ModelSpec::param_layout`]): dense
//! layers store `[W, b]` as two tensors, everything else one tensor —
//! bit-identical to what a `runtime::ModelState` checkpoint holds, so
//! the legacy formats convert losslessly.
//!
//! Mapping to the paper: the spec JSON carries `(dims, K budgets,
//! seed)` — everything §4.2's hash pair `(h, ξ)` needs to rebuild the
//! virtual matrices — and for a hashed layer the single tensor is
//! exactly the `K^ℓ` bucket values `w` of Eq. 7. Nothing about the
//! `n × (m+1)` virtual matrix is stored; `HNMB` file size therefore
//! scales with the *compressed* parameter count, and the v2 codecs
//! (`int8`, k-means `codebook` — Deep Compression's weight-sharing
//! stage) stack a further ~4× on those stored values.
//!
//! [`ModelBundle::load`] is the trust boundary: it verifies magic,
//! version, structure (section table, alignment, codec tags, code
//! ranges), checksum, spec validity and tensor shapes, and reports each
//! failure as a distinct [`ModelError`]. Every length is bounded by the
//! actual file size *before* any allocation, so a hostile header can
//! produce an error but never an OOM. `save` writes the struct as-is
//! (fields are public so tests can construct corrupt bundles
//! deliberately).

use super::quant::{
    decode_int8, quantize_tensor, Encoding, QuantSpec, CODEC_CODEBOOK, CODEC_F32, CODEC_INT8,
    MAX_CODEBOOK,
};
use super::{ModelError, ModelSpec};
use crate::hash::xxh32_bytes;
use crate::nn::{EmbedBag, LayerKind, Network};
use std::path::Path;

/// Current bundle format version. Readers accept any version `<=` this
/// and reject newer files with [`ModelError::FutureVersion`].
pub const BUNDLE_VERSION: u32 = 2;

/// Payload alignment of v2 sections: every tensor payload starts on a
/// 64-byte boundary (cache line; a multiple of `align_of::<f32>()`), so
/// an mmap'd f32 section can be borrowed in place as `&[f32]`.
pub const SECTION_ALIGN: usize = 64;

pub(crate) const MAGIC: &[u8; 4] = b"HNMB";
pub(crate) const CHECKSUM_SEED: u32 = 0x4D42;

/// Round `pos` up to the next [`SECTION_ALIGN`] boundary.
fn align_up(pos: usize) -> Option<usize> {
    pos.checked_add(SECTION_ALIGN - 1).map(|p| p & !(SECTION_ALIGN - 1))
}

/// One complete, self-describing model: spec + parameter tensors.
///
/// # Examples
///
/// Train-side packaging and serve-side reconstruction are exact
/// inverses, byte- and bit-level:
///
/// ```
/// use hashednets::model::{Method, ModelBundle, ModelSpec};
/// use hashednets::nn::Network;
/// use hashednets::util::rng::Pcg32;
///
/// let spec = ModelSpec::new(
///     "demo", Method::Hashnet, vec![8, 6, 3], vec![14, 7], 0x9E37_79B9, 4,
/// ).unwrap();
/// let mut net = Network::from_spec(&spec).unwrap();
/// net.init(&mut Pcg32::new(1, 1));
///
/// let bundle = net.to_bundle(&spec).unwrap();
/// let bytes = bundle.to_bytes(); // "HNMB" | v2 | spec | sections | payloads | xxh32
/// assert_eq!(&bytes[..4], b"HNMB");
/// // a hashed layer ships only its K bucket values (Eq. 7): 14 and 7 here
/// assert_eq!(bundle.n_params(), 21);
///
/// let back = ModelBundle::from_bytes(&bytes).unwrap();
/// let served = Network::from_bundle(&back).unwrap();
/// assert_eq!(served.layers[0].params, net.layers[0].params); // bit-exact
/// ```
#[derive(Debug, Clone)]
pub struct ModelBundle {
    pub spec: ModelSpec,
    /// Parameter tensors in [`ModelSpec::param_layout`] order — always
    /// the *decoded* (dequantized) values, which is what predictions
    /// use.
    pub params: Vec<Vec<f32>>,
    /// Per-tensor storage codec (parallel to `params`). For the lossy
    /// codecs the stored codes are authoritative on save, so a
    /// `save → load → save` round trip is byte-exact.
    pub encodings: Vec<Encoding>,
    /// Format version this bundle was read as (== [`BUNDLE_VERSION`]
    /// for freshly built bundles).
    pub version: u32,
}

/// One entry of a parsed (v1 or v2) bundle: where a tensor's encoded
/// payload lives. `n_elems` is the decoded f32 count; `offset` is
/// absolute in the file.
pub(crate) struct RawSection {
    pub codec: u32,
    pub n_elems: usize,
    pub offset: usize,
    pub enc_len: usize,
}

/// A structurally validated bundle: header fields plus the section
/// table, with the checksum verified and the spec parsed — everything
/// except decoding the payloads. [`crate::model::map::BundleMap`] keeps
/// exactly this and borrows payloads lazily.
pub(crate) struct RawBundle {
    pub version: u32,
    pub spec: ModelSpec,
    pub sections: Vec<RawSection>,
}

impl ModelBundle {
    /// Build an (unquantized) bundle, validating that `params` matches
    /// the spec's layout.
    pub fn new(spec: ModelSpec, params: Vec<Vec<f32>>) -> Result<ModelBundle, ModelError> {
        spec.validate()?;
        let encodings = vec![Encoding::F32; params.len()];
        let b = ModelBundle { spec, params, encodings, version: BUNDLE_VERSION };
        b.check_shapes()?;
        Ok(b)
    }

    /// Verify the tensors against the spec's layout, and the encodings
    /// against the tensors.
    pub fn check_shapes(&self) -> Result<(), ModelError> {
        let expect = self.spec.param_layout();
        let got: Vec<usize> = self.params.iter().map(Vec::len).collect();
        if got != expect {
            return Err(ModelError::ShapeMismatch(format!(
                "model '{}' ({}, dims {:?}) expects tensor lengths {:?}, got {:?}",
                self.spec.name, self.spec.method, self.spec.dims, expect, got
            )));
        }
        if self.encodings.len() != self.params.len() {
            return Err(ModelError::ShapeMismatch(format!(
                "bundle has {} tensors but {} encodings",
                self.params.len(),
                self.encodings.len()
            )));
        }
        for (i, (p, e)) in self.params.iter().zip(&self.encodings).enumerate() {
            if let Some(n) = e.code_len() {
                if n != p.len() {
                    return Err(ModelError::ShapeMismatch(format!(
                        "tensor {i}: {} decoded values but {n} {} codes",
                        p.len(),
                        e.codec_name()
                    )));
                }
            }
        }
        Ok(())
    }

    /// Re-encode every tensor with `spec`, replacing `params` with the
    /// dequantized values — so anything predicting from this bundle
    /// (eval, serve) sees exactly the precision the file will carry.
    pub fn quantize(&self, spec: QuantSpec) -> Result<ModelBundle, ModelError> {
        self.check_shapes()?;
        let mut params = Vec::with_capacity(self.params.len());
        let mut encodings = Vec::with_capacity(self.params.len());
        for p in &self.params {
            let (e, decoded) = quantize_tensor(p, spec);
            params.push(decoded);
            encodings.push(e);
        }
        Ok(ModelBundle { spec: self.spec.clone(), params, encodings, version: BUNDLE_VERSION })
    }

    /// Total stored f32 count across tensors (logical, pre-codec).
    pub fn n_params(&self) -> usize {
        self.params.iter().map(Vec::len).sum()
    }

    /// Logical f32 payload size of the parameters alone.
    pub fn param_bytes(&self) -> usize {
        4 * self.n_params()
    }

    /// Encoded payload size under the current codecs (excluding header,
    /// section table, padding and checksum) — the number the
    /// accuracy/size frontier reports.
    pub fn encoded_param_bytes(&self) -> usize {
        self.params
            .iter()
            .zip(&self.encodings)
            .map(|(p, e)| e.encoded_len(e.code_len().unwrap_or(p.len())))
            .sum()
    }

    /// `true` if any tensor uses a lossy codec.
    pub fn is_quantized(&self) -> bool {
        self.encodings.iter().any(|e| !matches!(e, Encoding::F32))
    }

    // -- serialization ---------------------------------------------------

    /// Serialize as format v2 (the only version the writer produces).
    pub fn to_bytes(&self) -> Vec<u8> {
        let spec_json = self.spec.to_json_string();
        let n = self.params.len();
        // plan the canonical section layout first
        let header_end = 12 + spec_json.len() + 4 + 16 * n;
        let mut entries = Vec::with_capacity(n);
        let mut pos = header_end;
        for (p, enc) in self.params.iter().zip(&self.encodings) {
            let n_elems = enc.code_len().unwrap_or(p.len());
            let enc_len = enc.encoded_len(n_elems);
            pos = align_up(pos).expect("bundle exceeds usize");
            entries.push((enc.codec_tag(), n_elems, pos, enc_len));
            pos += enc_len;
        }
        let mut out = Vec::with_capacity(pos + 4);
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&BUNDLE_VERSION.to_le_bytes());
        out.extend_from_slice(&(spec_json.len() as u32).to_le_bytes());
        out.extend_from_slice(spec_json.as_bytes());
        out.extend_from_slice(&(n as u32).to_le_bytes());
        for &(codec, n_elems, offset, enc_len) in &entries {
            out.extend_from_slice(&codec.to_le_bytes());
            out.extend_from_slice(&(n_elems as u32).to_le_bytes());
            out.extend_from_slice(&(offset as u32).to_le_bytes());
            out.extend_from_slice(&(enc_len as u32).to_le_bytes());
        }
        for ((p, enc), &(_, _, offset, _)) in
            self.params.iter().zip(&self.encodings).zip(&entries)
        {
            out.resize(offset, 0); // zero padding up to the aligned offset
            match enc {
                Encoding::F32 => {
                    for v in p {
                        out.extend_from_slice(&v.to_le_bytes());
                    }
                }
                Encoding::Int8 { min, scale, codes } => {
                    out.extend_from_slice(&min.to_le_bytes());
                    out.extend_from_slice(&scale.to_le_bytes());
                    out.extend_from_slice(codes);
                }
                Encoding::Codebook { table, codes } => {
                    out.extend_from_slice(&(table.len() as u32).to_le_bytes());
                    for t in table {
                        out.extend_from_slice(&t.to_le_bytes());
                    }
                    out.extend_from_slice(codes);
                }
            }
        }
        let sum = xxh32_bytes(&out, CHECKSUM_SEED);
        out.extend_from_slice(&sum.to_le_bytes());
        out
    }

    /// Serialize as legacy format v1 (dense length-prefixed tensors, no
    /// section table). Only f32 bundles have a v1 representation; kept
    /// for compat tooling, golden fixtures and the v1-vs-v2 load bench.
    pub fn to_bytes_v1(&self) -> Result<Vec<u8>, ModelError> {
        if self.is_quantized() {
            return Err(ModelError::InvalidSpec(
                "format v1 cannot carry quantized tensors (re-encode as f32 first)".into(),
            ));
        }
        let spec_json = self.spec.to_json_string();
        let mut out = Vec::with_capacity(24 + spec_json.len() + self.param_bytes());
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&1u32.to_le_bytes());
        out.extend_from_slice(&(spec_json.len() as u32).to_le_bytes());
        out.extend_from_slice(spec_json.as_bytes());
        out.extend_from_slice(&(self.params.len() as u32).to_le_bytes());
        for p in &self.params {
            out.extend_from_slice(&(p.len() as u32).to_le_bytes());
            for v in p {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
        let sum = xxh32_bytes(&out, CHECKSUM_SEED);
        out.extend_from_slice(&sum.to_le_bytes());
        Ok(out)
    }

    pub fn from_bytes(bytes: &[u8]) -> Result<ModelBundle, ModelError> {
        let raw = parse(bytes)?;
        let mut params = Vec::with_capacity(raw.sections.len());
        let mut encodings = Vec::with_capacity(raw.sections.len());
        for s in &raw.sections {
            let (p, e) = decode_section(bytes, s);
            params.push(p);
            encodings.push(e);
        }
        let bundle = ModelBundle { spec: raw.spec, params, encodings, version: raw.version };
        bundle.check_shapes()?;
        Ok(bundle)
    }

    /// Write the bundle to one file, atomically and durably: the bytes
    /// go to a sibling temp file, are fsynced, the temp is renamed into
    /// place, and the parent directory is fsynced so the rename itself
    /// survives a crash. A crash mid-save — or a concurrent
    /// `{"cmd":"load"}` / `{"cmd":"reload"}` reading while a retrain
    /// overwrites — can therefore only ever observe the old complete
    /// bundle or the new complete bundle, never a torn prefix, and a
    /// completed save cannot be rolled back by a power cut. (The
    /// checksum in [`ModelBundle::from_bytes`] would catch a tear after
    /// the fact; this makes the window not exist.)
    pub fn save(&self, path: &Path) -> Result<(), ModelError> {
        use std::io::Write as _;
        let file_name = path
            .file_name()
            .and_then(|n| n.to_str())
            .ok_or_else(|| {
                ModelError::Io(std::io::Error::new(
                    std::io::ErrorKind::InvalidInput,
                    format!("bundle path has no file name: {}", path.display()),
                ))
            })?;
        // Same directory as the target so the rename cannot cross a
        // filesystem boundary (cross-device rename is not atomic).
        let tmp = path.with_file_name(format!(".{file_name}.tmp.{}", std::process::id()));
        let write_and_sync = (|| -> std::io::Result<()> {
            let mut f = std::fs::File::create(&tmp)?;
            f.write_all(&self.to_bytes())?;
            // data must be durable *before* the rename publishes it,
            // or a crash could leave a complete-looking name pointing
            // at unwritten blocks
            f.sync_all()?;
            std::fs::rename(&tmp, path)?;
            // the rename lives in the directory, not the file: without
            // this fsync a crash can resurrect the old name (or, for a
            // first save, lose the file entirely) after `save` returned
            #[cfg(unix)]
            {
                let dir = match path.parent() {
                    Some(d) if !d.as_os_str().is_empty() => d,
                    _ => Path::new("."),
                };
                std::fs::File::open(dir)?.sync_all()?;
            }
            Ok(())
        })();
        if let Err(e) = write_and_sync {
            let _ = std::fs::remove_file(&tmp);
            return Err(ModelError::Io(e));
        }
        Ok(())
    }

    /// Read and fully validate a bundle file.
    pub fn load(path: &Path) -> Result<ModelBundle, ModelError> {
        let bytes = std::fs::read(path)?;
        ModelBundle::from_bytes(&bytes)
    }
}

/// Structural + checksum + spec validation shared by
/// [`ModelBundle::from_bytes`] and the mmap'd
/// [`crate::model::map::BundleMap`]: returns the section table without
/// decoding any payload. Validation order matches the original v1
/// reader — structure first (so a hostile length can never reach an
/// allocation), then checksum, then spec parse; shape checks against
/// the spec happen in the callers.
pub(crate) fn parse(bytes: &[u8]) -> Result<RawBundle, ModelError> {
    let read_u32 = |off: usize, what: &'static str| -> Result<u32, ModelError> {
        bytes
            .get(off..off + 4)
            .map(|b| u32::from_le_bytes(b.try_into().unwrap()))
            .ok_or(ModelError::Truncated(what))
    };
    if bytes.len() < 4 {
        return Err(ModelError::Truncated("magic"));
    }
    if &bytes[..4] != MAGIC {
        return Err(ModelError::BadMagic);
    }
    let version = read_u32(4, "version")?;
    // version 0 never existed — report it the same way as a version
    // from the future: a number this reader has no layout for
    if version == 0 || version > BUNDLE_VERSION {
        return Err(ModelError::FutureVersion { found: version, supported: BUNDLE_VERSION });
    }
    let spec_len = read_u32(8, "spec length")? as usize;
    // everything below the trailing checksum word is the body
    let body_end = bytes
        .len()
        .checked_sub(4)
        .filter(|&e| e >= 12)
        .ok_or(ModelError::Truncated("checksum"))?;
    let mut off = 12;
    if spec_len > body_end - off {
        return Err(ModelError::Truncated("spec json"));
    }
    let spec_bytes = &bytes[off..off + spec_len];
    off += spec_len;
    if off + 4 > body_end {
        return Err(ModelError::Truncated("tensor count"));
    }
    let n_tensors = read_u32(off, "tensor count")? as usize;
    off += 4;
    let sections = if version == 1 {
        parse_v1_sections(bytes, off, n_tensors, body_end)?
    } else {
        parse_v2_sections(bytes, off, n_tensors, body_end)?
    };
    let stored = read_u32(body_end, "checksum")?;
    let computed = xxh32_bytes(&bytes[..body_end], CHECKSUM_SEED);
    if stored != computed {
        return Err(ModelError::BadChecksum { stored, computed });
    }
    let spec_text = std::str::from_utf8(spec_bytes)
        .map_err(|_| ModelError::InvalidSpec("spec json is not utf-8".into()))?;
    let spec = ModelSpec::from_json_str(spec_text)?;
    Ok(RawBundle { version, spec, sections })
}

/// v1 body: length-prefixed f32 tensors, back to back.
fn parse_v1_sections(
    bytes: &[u8],
    mut off: usize,
    n_tensors: usize,
    body_end: usize,
) -> Result<Vec<RawSection>, ModelError> {
    // every tensor needs at least its 4-byte length word, so a count
    // beyond this is lying — reject before trusting it with an
    // allocation
    if n_tensors > (body_end - off) / 4 {
        return Err(ModelError::Truncated("tensor count"));
    }
    let mut sections = Vec::with_capacity(n_tensors);
    for _ in 0..n_tensors {
        if off + 4 > body_end {
            return Err(ModelError::Truncated("tensor length"));
        }
        let len = u32::from_le_bytes(bytes[off..off + 4].try_into().unwrap()) as usize;
        off += 4;
        let byte_len = len.checked_mul(4).ok_or(ModelError::Truncated("tensor data"))?;
        if byte_len > body_end - off {
            return Err(ModelError::Truncated("tensor data"));
        }
        sections.push(RawSection { codec: CODEC_F32, n_elems: len, offset: off, enc_len: byte_len });
        off += byte_len;
    }
    if off != body_end {
        return Err(ModelError::InvalidSpec(format!(
            "{} trailing bytes after tensors",
            body_end - off
        )));
    }
    Ok(sections)
}

/// v2 body: fixed-size section table, then canonically packed,
/// 64-byte-aligned payloads. Everything a hostile header could inflate
/// (`n_tens`, `n_elems`, `enc_len`, `offset`, codebook `table_len`) is
/// checked against the real file length here, before any allocation.
fn parse_v2_sections(
    bytes: &[u8],
    table_start: usize,
    n_tensors: usize,
    body_end: usize,
) -> Result<Vec<RawSection>, ModelError> {
    let bad = |i: usize, why: String| ModelError::BadSection(format!("tensor {i}: {why}"));
    // each section occupies 16 table bytes — an n_tens beyond that is
    // lying about the file it lives in
    if n_tensors > (body_end - table_start) / 16 {
        return Err(ModelError::Truncated("section table"));
    }
    let mut sections = Vec::with_capacity(n_tensors);
    for i in 0..n_tensors {
        let e = table_start + 16 * i;
        let word = |j: usize| u32::from_le_bytes(bytes[e + 4 * j..e + 4 * j + 4].try_into().unwrap());
        let (codec, n_elems, offset, enc_len) =
            (word(0), word(1) as usize, word(2) as usize, word(3) as usize);
        if codec > CODEC_CODEBOOK {
            return Err(bad(i, format!("unknown codec tag {codec}")));
        }
        sections.push(RawSection { codec, n_elems, offset, enc_len });
    }
    let mut pos = table_start + 16 * n_tensors;
    for (i, s) in sections.iter().enumerate() {
        let expected = align_up(pos).ok_or_else(|| bad(i, "offset overflow".into()))?;
        if s.offset != expected {
            return Err(bad(
                i,
                format!(
                    "payload offset {} is not the canonical {SECTION_ALIGN}-byte-aligned {expected}",
                    s.offset
                ),
            ));
        }
        let end = s.offset.checked_add(s.enc_len).ok_or_else(|| bad(i, "length overflow".into()))?;
        if end > body_end {
            return Err(ModelError::Truncated("tensor data"));
        }
        // enc_len ↔ n_elems consistency pins every decode allocation to
        // at most the real payload length
        let want = match s.codec {
            CODEC_F32 => s.n_elems.checked_mul(4),
            CODEC_INT8 => s.n_elems.checked_add(8),
            _ => {
                if s.enc_len < 4 {
                    return Err(bad(i, "codebook payload shorter than its table length".into()));
                }
                let tl =
                    u32::from_le_bytes(bytes[s.offset..s.offset + 4].try_into().unwrap()) as usize;
                if tl == 0 || tl > MAX_CODEBOOK {
                    return Err(bad(i, format!("codebook table length {tl} (valid: 1..={MAX_CODEBOOK})")));
                }
                let codes_at = s.offset + 4 + 4 * tl;
                let want = (4 + 4 * tl).checked_add(s.n_elems);
                if want == Some(s.enc_len) {
                    // every index must point inside the table
                    if let Some(p) = bytes[codes_at..end].iter().position(|&c| c as usize >= tl) {
                        return Err(bad(
                            i,
                            format!(
                                "code {} at element {p} indexes past the {tl}-entry table",
                                bytes[codes_at + p]
                            ),
                        ));
                    }
                }
                want
            }
        };
        if want != Some(s.enc_len) {
            return Err(bad(
                i,
                format!("encoded length {} does not match {} elements", s.enc_len, s.n_elems),
            ));
        }
        pos = end;
    }
    if pos != body_end {
        return Err(ModelError::InvalidSpec(format!(
            "{} trailing bytes after tensors",
            body_end - pos
        )));
    }
    Ok(sections)
}

/// Decode one validated section into (dequantized values, encoding).
/// Infallible by construction: [`parse`] already bounded every length
/// and index against the real bytes.
pub(crate) fn decode_section(bytes: &[u8], s: &RawSection) -> (Vec<f32>, Encoding) {
    let p = &bytes[s.offset..s.offset + s.enc_len];
    let f32_at = |b: &[u8], at: usize| f32::from_le_bytes(b[at..at + 4].try_into().unwrap());
    match s.codec {
        CODEC_INT8 => {
            let (min, scale) = (f32_at(p, 0), f32_at(p, 4));
            let codes = p[8..].to_vec();
            (decode_int8(min, scale, &codes), Encoding::Int8 { min, scale, codes })
        }
        CODEC_CODEBOOK => {
            let tl = u32::from_le_bytes(p[0..4].try_into().unwrap()) as usize;
            let table: Vec<f32> = (0..tl).map(|i| f32_at(p, 4 + 4 * i)).collect();
            let codes = p[4 + 4 * tl..].to_vec();
            let decoded = codes.iter().map(|&c| table[c as usize]).collect();
            (decoded, Encoding::Codebook { table, codes })
        }
        _ => {
            let v = (0..s.n_elems).map(|i| f32_at(p, 4 * i)).collect();
            (v, Encoding::F32)
        }
    }
}

impl Network {
    /// Build the network skeleton a spec describes (parameters zeroed;
    /// call [`Network::init`] to He-initialize, or load a bundle).
    pub fn from_spec(spec: &ModelSpec) -> Result<Network, ModelError> {
        spec.validate()?;
        if spec.embedding_shape().is_some() {
            return Err(ModelError::InvalidSpec(
                "hashed_embedding specs are served by nn::EmbedBag, not Network".into(),
            ));
        }
        Ok(Network::from_dims(&spec.dims, spec.layer_kinds(), spec.seed_base))
    }

    /// Reconstruct the full model a bundle stores: skeleton from the
    /// spec, parameters copied bit-exactly from the (decoded) tensors.
    /// For the zero-copy variant see
    /// [`Network::from_bundle_map`](crate::model::map::BundleMap).
    pub fn from_bundle(bundle: &ModelBundle) -> Result<Network, ModelError> {
        bundle.check_shapes()?;
        let mut net = Network::from_spec(&bundle.spec)?;
        let mut it = bundle.params.iter();
        for layer in &mut net.layers {
            match layer.kind {
                LayerKind::Dense => {
                    let w = it.next().expect("layout checked");
                    let b = it.next().expect("layout checked");
                    layer.params[..w.len()].copy_from_slice(w);
                    layer.params[w.len()..].copy_from_slice(b);
                }
                _ => {
                    let p = it.next().expect("layout checked");
                    layer.params.copy_from_slice(p);
                }
            }
        }
        Ok(net)
    }

    /// Package this network's parameters under `spec` — the inverse of
    /// [`Network::from_bundle`]. Fails when the spec does not describe
    /// this network (wrong dims or layer kinds).
    pub fn to_bundle(&self, spec: &ModelSpec) -> Result<ModelBundle, ModelError> {
        spec.validate()?;
        if spec.embedding_shape().is_some() {
            return Err(ModelError::ShapeMismatch(format!(
                "spec '{}' describes a hashed_embedding, not a feed-forward network",
                spec.name
            )));
        }
        let mut dims: Vec<usize> = vec![self.n_in()];
        dims.extend(self.layers.iter().map(|l| l.n));
        if dims != spec.dims {
            return Err(ModelError::ShapeMismatch(format!(
                "network dims {:?} do not match spec '{}' dims {:?}",
                dims, spec.name, spec.dims
            )));
        }
        for (l, (layer, kind)) in self.layers.iter().zip(spec.layer_kinds()).enumerate() {
            if layer.kind != kind {
                return Err(ModelError::ShapeMismatch(format!(
                    "layer {l} is {:?} but spec '{}' describes {:?}",
                    layer.kind, spec.name, kind
                )));
            }
        }
        let mut params = Vec::new();
        for layer in &self.layers {
            match layer.kind {
                LayerKind::Dense => {
                    let nm = layer.n * layer.m;
                    params.push(layer.params[..nm].to_vec());
                    params.push(layer.params[nm..].to_vec());
                }
                _ => params.push(layer.params.to_vec()),
            }
        }
        ModelBundle::new(spec.clone(), params)
    }
}

impl EmbedBag {
    /// Reconstruct the embedding table a bundle stores: identity from
    /// the spec, bucket array copied bit-exactly from the single
    /// (decoded) tensor.
    pub fn from_bundle(bundle: &ModelBundle) -> Result<EmbedBag, ModelError> {
        bundle.check_shapes()?;
        let w = bundle.params.first().cloned().ok_or_else(|| {
            ModelError::ShapeMismatch("embedding bundle carries no tensor".into())
        })?;
        EmbedBag::from_spec(&bundle.spec, w)
    }

    /// Package the bucket array under `spec` — the inverse of
    /// [`EmbedBag::from_bundle`]. Fails when the spec does not describe
    /// this table.
    pub fn to_bundle(&self, spec: &ModelSpec) -> Result<ModelBundle, ModelError> {
        spec.validate()?;
        let Some((nc, dim, k, mode)) = spec.embedding_shape() else {
            return Err(ModelError::ShapeMismatch(format!(
                "spec '{}' does not describe a hashed_embedding",
                spec.name
            )));
        };
        if (nc, dim, k, mode, spec.seed_base)
            != (self.num_categories, self.dim, self.k(), self.mode, self.seed_base)
        {
            return Err(ModelError::ShapeMismatch(format!(
                "embedding table ({}x{}, k={}, {}, seed {:#010x}) does not match spec '{}'",
                self.num_categories,
                self.dim,
                self.k(),
                self.mode.as_str(),
                self.seed_base,
                spec.name
            )));
        }
        ModelBundle::new(spec.clone(), vec![self.w.to_vec()])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{BagMode, Method};
    use crate::util::rng::Pcg32;

    fn spec(method: Method) -> ModelSpec {
        ModelSpec::new("unit", method, vec![6, 5, 3], vec![14, 7], 0x9E37_79B9, 4).unwrap()
    }

    #[test]
    fn bytes_roundtrip_bit_exact() {
        let mut net = Network::from_spec(&spec(Method::Hashnet)).unwrap();
        net.init(&mut Pcg32::new(5, 5));
        let bundle = net.to_bundle(&spec(Method::Hashnet)).unwrap();
        let back = ModelBundle::from_bytes(&bundle.to_bytes()).unwrap();
        assert_eq!(back.spec, bundle.spec);
        assert_eq!(back.params, bundle.params);
        assert_eq!(back.version, BUNDLE_VERSION);
    }

    #[test]
    fn v2_sections_are_aligned_and_canonical() {
        let mut net = Network::from_spec(&spec(Method::Nn)).unwrap();
        net.init(&mut Pcg32::new(9, 9));
        let bytes = net.to_bundle(&spec(Method::Nn)).unwrap().to_bytes();
        let raw = parse(&bytes).unwrap();
        assert_eq!(raw.version, BUNDLE_VERSION);
        assert_eq!(raw.sections.len(), 4); // [W0, b0, W1, b1]
        for s in &raw.sections {
            assert_eq!(s.offset % SECTION_ALIGN, 0, "payloads start 64-byte aligned");
        }
    }

    #[test]
    fn quantized_roundtrip_is_byte_exact_and_smaller() {
        let mut net = Network::from_spec(&spec(Method::Hashnet)).unwrap();
        net.init(&mut Pcg32::new(5, 5));
        let f32_bundle = net.to_bundle(&spec(Method::Hashnet)).unwrap();
        for q in [QuantSpec::Int8, QuantSpec::Codebook(8)] {
            let qb = f32_bundle.quantize(q).unwrap();
            assert!(qb.is_quantized());
            assert!(qb.encoded_param_bytes() < f32_bundle.encoded_param_bytes());
            let bytes = qb.to_bytes();
            let back = ModelBundle::from_bytes(&bytes).unwrap();
            assert_eq!(back.params, qb.params, "{q:?} decode must match");
            assert_eq!(back.encodings, qb.encodings);
            assert_eq!(back.to_bytes(), bytes, "save→load→save byte-exact for {q:?}");
        }
    }

    #[test]
    fn v1_writer_reads_back_as_v1() {
        let mut net = Network::from_spec(&spec(Method::Hashnet)).unwrap();
        net.init(&mut Pcg32::new(5, 5));
        let bundle = net.to_bundle(&spec(Method::Hashnet)).unwrap();
        let v1 = bundle.to_bytes_v1().unwrap();
        let back = ModelBundle::from_bytes(&v1).unwrap();
        assert_eq!(back.version, 1);
        assert_eq!(back.params, bundle.params);
        // and the v1 writer round-trips its own bytes exactly
        assert_eq!(back.to_bytes_v1().unwrap(), v1);
        // quantized bundles have no v1 representation
        assert!(bundle.quantize(QuantSpec::Int8).unwrap().to_bytes_v1().is_err());
    }

    #[test]
    fn dense_split_layout_matches_state_convention() {
        let s = spec(Method::Nn);
        let mut net = Network::from_spec(&s).unwrap();
        net.init(&mut Pcg32::new(7, 7));
        let b = net.to_bundle(&s).unwrap();
        // [W0 (5*6), b0 (5), W1 (3*5), b1 (3)]
        assert_eq!(
            b.params.iter().map(Vec::len).collect::<Vec<_>>(),
            vec![30, 5, 15, 3]
        );
        let back = Network::from_bundle(&b).unwrap();
        assert_eq!(back.layers[0].params, net.layers[0].params);
        assert_eq!(back.layers[1].params, net.layers[1].params);
    }

    #[test]
    fn to_bundle_rejects_wrong_spec() {
        let mut net = Network::from_spec(&spec(Method::Hashnet)).unwrap();
        net.init(&mut Pcg32::new(1, 1));
        // wrong kind
        assert!(matches!(
            net.to_bundle(&spec(Method::Nn)),
            Err(ModelError::ShapeMismatch(_))
        ));
        // wrong dims
        let other =
            ModelSpec::new("o", Method::Hashnet, vec![6, 4, 3], vec![14, 7], 1, 4).unwrap();
        assert!(matches!(
            net.to_bundle(&other),
            Err(ModelError::ShapeMismatch(_))
        ));
    }

    #[test]
    fn embedding_bundle_roundtrip_bit_exact() {
        let s = ModelSpec::embedding("bag", 1_000, 8, 64, BagMode::Mean, 0x9E37_79B9, 4).unwrap();
        let mut bag = EmbedBag::new(1_000, 8, 64, BagMode::Mean, 0x9E37_79B9);
        bag.init(&mut Pcg32::new(3, 3));
        let bundle = bag.to_bundle(&s).unwrap();
        assert_eq!(bundle.n_params(), 64); // K buckets only, never nc*dim
        let back = ModelBundle::from_bytes(&bundle.to_bytes()).unwrap();
        let served = EmbedBag::from_bundle(&back).unwrap();
        assert_eq!(served.w, bag.w);
        assert_eq!(served.mode, BagMode::Mean);
        // the feed-forward loader refuses the same bundle with a typed
        // error instead of tripping the from_dims assert
        assert!(matches!(
            Network::from_bundle(&back),
            Err(ModelError::InvalidSpec(_))
        ));
        // and the embedding loader refuses feed-forward bundles
        let dense = spec(Method::Hashnet);
        let mut net = Network::from_spec(&dense).unwrap();
        net.init(&mut Pcg32::new(1, 1));
        let nb = net.to_bundle(&dense).unwrap();
        assert!(EmbedBag::from_bundle(&nb).is_err());
    }

    #[test]
    fn new_validates_param_layout() {
        let s = spec(Method::Hashnet);
        assert!(ModelBundle::new(s.clone(), vec![vec![0.0; 14], vec![0.0; 7]]).is_ok());
        assert!(matches!(
            ModelBundle::new(s, vec![vec![0.0; 13], vec![0.0; 7]]),
            Err(ModelError::ShapeMismatch(_))
        ));
    }

    #[test]
    fn misaligned_section_offset_is_a_typed_error() {
        let mut net = Network::from_spec(&spec(Method::Hashnet)).unwrap();
        net.init(&mut Pcg32::new(2, 2));
        let mut bytes = net.to_bundle(&spec(Method::Hashnet)).unwrap().to_bytes();
        // the first section's offset field lives at
        // 12 + spec_len + 4 (count) + 8 (codec, n_elems)
        let spec_len =
            u32::from_le_bytes(bytes[8..12].try_into().unwrap()) as usize;
        let off_field = 12 + spec_len + 4 + 8;
        let old = u32::from_le_bytes(bytes[off_field..off_field + 4].try_into().unwrap());
        bytes[off_field..off_field + 4].copy_from_slice(&(old + 1).to_le_bytes());
        // refresh the checksum so the structural check is what trips
        let body_end = bytes.len() - 4;
        let sum = xxh32_bytes(&bytes[..body_end], CHECKSUM_SEED);
        bytes[body_end..].copy_from_slice(&sum.to_le_bytes());
        assert!(matches!(
            ModelBundle::from_bytes(&bytes),
            Err(ModelError::BadSection(_))
        ));
    }
}
