//! [`ModelBundle`]: the versioned single-file model artifact, and the
//! [`Network`] construction/persistence glue
//! ([`Network::from_spec`] / [`Network::from_bundle`] /
//! [`Network::to_bundle`]).
//!
//! ## File format (version 1)
//!
//! ```text
//! magic    4 B   "HNMB"
//! version  4 B   u32 LE (currently 1)
//! spec_len 4 B   u32 LE
//! spec     …     ModelSpec as UTF-8 JSON (deterministic key order)
//! n_tens   4 B   u32 LE
//! tensors  …     per tensor: u32 LE length + length × f32 LE
//! checksum 4 B   u32 LE — xxh32 over every preceding byte
//! ```
//!
//! Tensors use the artifact layout ([`ModelSpec::param_layout`]): dense
//! layers store `[W, b]` as two tensors, everything else one tensor —
//! bit-identical to what a `runtime::ModelState` checkpoint holds, so
//! the legacy formats convert losslessly.
//!
//! Mapping to the paper: the spec JSON carries `(dims, K budgets,
//! seed)` — everything §4.2's hash pair `(h, ξ)` needs to rebuild the
//! virtual matrices — and for a hashed layer the single tensor is
//! exactly the `K^ℓ` bucket values `w` of Eq. 7. Nothing about the
//! `n × (m+1)` virtual matrix is stored; `HNMB` file size therefore
//! scales with the *compressed* parameter count, which is the paper's
//! deployment claim realized as a file format.
//!
//! [`ModelBundle::load`] is the trust boundary: it verifies magic,
//! version, structure, checksum, spec validity and tensor shapes, and
//! reports each failure as a distinct [`ModelError`]. `save` writes the
//! struct as-is (fields are public so tests can construct corrupt
//! bundles deliberately).

use super::{ModelError, ModelSpec};
use crate::hash::xxh32_bytes;
use crate::nn::{EmbedBag, LayerKind, Network};
use std::path::Path;

/// Current bundle format version. Readers accept any version `<=` this
/// and reject newer files with [`ModelError::FutureVersion`].
pub const BUNDLE_VERSION: u32 = 1;

const MAGIC: &[u8; 4] = b"HNMB";
const CHECKSUM_SEED: u32 = 0x4D42;

/// One complete, self-describing model: spec + parameter tensors.
///
/// # Examples
///
/// Train-side packaging and serve-side reconstruction are exact
/// inverses, byte- and bit-level:
///
/// ```
/// use hashednets::model::{Method, ModelBundle, ModelSpec};
/// use hashednets::nn::Network;
/// use hashednets::util::rng::Pcg32;
///
/// let spec = ModelSpec::new(
///     "demo", Method::Hashnet, vec![8, 6, 3], vec![14, 7], 0x9E37_79B9, 4,
/// ).unwrap();
/// let mut net = Network::from_spec(&spec).unwrap();
/// net.init(&mut Pcg32::new(1, 1));
///
/// let bundle = net.to_bundle(&spec).unwrap();
/// let bytes = bundle.to_bytes(); // "HNMB" | version | spec JSON | tensors | xxh32
/// assert_eq!(&bytes[..4], b"HNMB");
/// // a hashed layer ships only its K bucket values (Eq. 7): 14 and 7 here
/// assert_eq!(bundle.n_params(), 21);
///
/// let back = ModelBundle::from_bytes(&bytes).unwrap();
/// let served = Network::from_bundle(&back).unwrap();
/// assert_eq!(served.layers[0].params, net.layers[0].params); // bit-exact
/// ```
#[derive(Debug, Clone)]
pub struct ModelBundle {
    pub spec: ModelSpec,
    /// Parameter tensors in [`ModelSpec::param_layout`] order.
    pub params: Vec<Vec<f32>>,
    /// Format version this bundle was read as (== [`BUNDLE_VERSION`]
    /// for freshly built bundles).
    pub version: u32,
}

impl ModelBundle {
    /// Build a bundle, validating that `params` matches the spec's
    /// layout.
    pub fn new(spec: ModelSpec, params: Vec<Vec<f32>>) -> Result<ModelBundle, ModelError> {
        spec.validate()?;
        let b = ModelBundle { spec, params, version: BUNDLE_VERSION };
        b.check_shapes()?;
        Ok(b)
    }

    /// Verify the tensors against the spec's layout.
    pub fn check_shapes(&self) -> Result<(), ModelError> {
        let expect = self.spec.param_layout();
        let got: Vec<usize> = self.params.iter().map(Vec::len).collect();
        if got != expect {
            return Err(ModelError::ShapeMismatch(format!(
                "model '{}' ({}, dims {:?}) expects tensor lengths {:?}, got {:?}",
                self.spec.name, self.spec.method, self.spec.dims, expect, got
            )));
        }
        Ok(())
    }

    /// Total stored f32 count across tensors.
    pub fn n_params(&self) -> usize {
        self.params.iter().map(Vec::len).sum()
    }

    /// On-disk payload size of the parameters alone.
    pub fn param_bytes(&self) -> usize {
        4 * self.n_params()
    }

    // -- serialization ---------------------------------------------------

    pub fn to_bytes(&self) -> Vec<u8> {
        let spec_json = self.spec.to_json_string();
        let mut out = Vec::with_capacity(24 + spec_json.len() + self.param_bytes());
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&BUNDLE_VERSION.to_le_bytes());
        out.extend_from_slice(&(spec_json.len() as u32).to_le_bytes());
        out.extend_from_slice(spec_json.as_bytes());
        out.extend_from_slice(&(self.params.len() as u32).to_le_bytes());
        for p in &self.params {
            out.extend_from_slice(&(p.len() as u32).to_le_bytes());
            for v in p {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
        let sum = xxh32_bytes(&out, CHECKSUM_SEED);
        out.extend_from_slice(&sum.to_le_bytes());
        out
    }

    pub fn from_bytes(bytes: &[u8]) -> Result<ModelBundle, ModelError> {
        let read_u32 = |off: usize, what: &'static str| -> Result<u32, ModelError> {
            bytes
                .get(off..off + 4)
                .map(|b| u32::from_le_bytes(b.try_into().unwrap()))
                .ok_or(ModelError::Truncated(what))
        };
        if bytes.len() < 4 {
            return Err(ModelError::Truncated("magic"));
        }
        if &bytes[..4] != MAGIC {
            return Err(ModelError::BadMagic);
        }
        let version = read_u32(4, "version")?;
        if version > BUNDLE_VERSION {
            return Err(ModelError::FutureVersion { found: version, supported: BUNDLE_VERSION });
        }
        let spec_len = read_u32(8, "spec length")? as usize;
        // everything below the trailing checksum word is the body
        let body_end = bytes
            .len()
            .checked_sub(4)
            .filter(|&e| e >= 12)
            .ok_or(ModelError::Truncated("checksum"))?;
        let mut off = 12;
        if off + spec_len > body_end {
            return Err(ModelError::Truncated("spec json"));
        }
        let spec_bytes = &bytes[off..off + spec_len];
        off += spec_len;
        if off + 4 > body_end {
            return Err(ModelError::Truncated("tensor count"));
        }
        let n_tensors = read_u32(off, "tensor count")? as usize;
        off += 4;
        // every tensor needs at least its 4-byte length word, so a
        // count beyond this is lying — reject before trusting it with
        // an allocation
        if n_tensors > (body_end - off) / 4 {
            return Err(ModelError::Truncated("tensor count"));
        }
        let mut params = Vec::with_capacity(n_tensors);
        for _ in 0..n_tensors {
            if off + 4 > body_end {
                return Err(ModelError::Truncated("tensor length"));
            }
            let len = read_u32(off, "tensor length")? as usize;
            off += 4;
            let byte_len = len.checked_mul(4).ok_or(ModelError::Truncated("tensor data"))?;
            if off + byte_len > body_end {
                return Err(ModelError::Truncated("tensor data"));
            }
            let mut v = Vec::with_capacity(len);
            for i in 0..len {
                let at = off + 4 * i;
                v.push(f32::from_le_bytes(bytes[at..at + 4].try_into().unwrap()));
            }
            off += byte_len;
            params.push(v);
        }
        if off != body_end {
            return Err(ModelError::InvalidSpec(format!(
                "{} trailing bytes after tensors",
                body_end - off
            )));
        }
        let stored = read_u32(body_end, "checksum")?;
        let computed = xxh32_bytes(&bytes[..body_end], CHECKSUM_SEED);
        if stored != computed {
            return Err(ModelError::BadChecksum { stored, computed });
        }
        let spec_text = std::str::from_utf8(spec_bytes)
            .map_err(|_| ModelError::InvalidSpec("spec json is not utf-8".into()))?;
        let spec = ModelSpec::from_json_str(spec_text)?;
        let bundle = ModelBundle { spec, params, version };
        bundle.check_shapes()?;
        Ok(bundle)
    }

    /// Write the bundle to one file, atomically: the bytes go to a
    /// sibling temp file, are fsynced, and the temp is renamed into
    /// place. A crash mid-save — or a concurrent `{"cmd":"load"}` /
    /// `{"cmd":"reload"}` reading while a retrain overwrites — can
    /// therefore only ever observe the old complete bundle or the new
    /// complete bundle, never a torn prefix. (The checksum in
    /// [`ModelBundle::from_bytes`] would catch a tear after the fact;
    /// this makes the window not exist.)
    pub fn save(&self, path: &Path) -> Result<(), ModelError> {
        use std::io::Write as _;
        let file_name = path
            .file_name()
            .and_then(|n| n.to_str())
            .ok_or_else(|| {
                ModelError::Io(std::io::Error::new(
                    std::io::ErrorKind::InvalidInput,
                    format!("bundle path has no file name: {}", path.display()),
                ))
            })?;
        // Same directory as the target so the rename cannot cross a
        // filesystem boundary (cross-device rename is not atomic).
        let tmp = path.with_file_name(format!(".{file_name}.tmp.{}", std::process::id()));
        let write_and_sync = (|| {
            let mut f = std::fs::File::create(&tmp)?;
            f.write_all(&self.to_bytes())?;
            // data must be durable *before* the rename publishes it,
            // or a crash could leave a complete-looking name pointing
            // at unwritten blocks
            f.sync_all()?;
            std::fs::rename(&tmp, path)
        })();
        if let Err(e) = write_and_sync {
            let _ = std::fs::remove_file(&tmp);
            return Err(ModelError::Io(e));
        }
        Ok(())
    }

    /// Read and fully validate a bundle file.
    pub fn load(path: &Path) -> Result<ModelBundle, ModelError> {
        let bytes = std::fs::read(path)?;
        ModelBundle::from_bytes(&bytes)
    }
}

impl Network {
    /// Build the network skeleton a spec describes (parameters zeroed;
    /// call [`Network::init`] to He-initialize, or load a bundle).
    pub fn from_spec(spec: &ModelSpec) -> Result<Network, ModelError> {
        spec.validate()?;
        if spec.embedding_shape().is_some() {
            return Err(ModelError::InvalidSpec(
                "hashed_embedding specs are served by nn::EmbedBag, not Network".into(),
            ));
        }
        Ok(Network::from_dims(&spec.dims, spec.layer_kinds(), spec.seed_base))
    }

    /// Reconstruct the full model a bundle stores: skeleton from the
    /// spec, parameters copied bit-exactly from the tensors.
    pub fn from_bundle(bundle: &ModelBundle) -> Result<Network, ModelError> {
        bundle.check_shapes()?;
        let mut net = Network::from_spec(&bundle.spec)?;
        let mut it = bundle.params.iter();
        for layer in &mut net.layers {
            match layer.kind {
                LayerKind::Dense => {
                    let w = it.next().expect("layout checked");
                    let b = it.next().expect("layout checked");
                    layer.params[..w.len()].copy_from_slice(w);
                    layer.params[w.len()..].copy_from_slice(b);
                }
                _ => {
                    let p = it.next().expect("layout checked");
                    layer.params.copy_from_slice(p);
                }
            }
        }
        Ok(net)
    }

    /// Package this network's parameters under `spec` — the inverse of
    /// [`Network::from_bundle`]. Fails when the spec does not describe
    /// this network (wrong dims or layer kinds).
    pub fn to_bundle(&self, spec: &ModelSpec) -> Result<ModelBundle, ModelError> {
        spec.validate()?;
        if spec.embedding_shape().is_some() {
            return Err(ModelError::ShapeMismatch(format!(
                "spec '{}' describes a hashed_embedding, not a feed-forward network",
                spec.name
            )));
        }
        let mut dims: Vec<usize> = vec![self.n_in()];
        dims.extend(self.layers.iter().map(|l| l.n));
        if dims != spec.dims {
            return Err(ModelError::ShapeMismatch(format!(
                "network dims {:?} do not match spec '{}' dims {:?}",
                dims, spec.name, spec.dims
            )));
        }
        for (l, (layer, kind)) in self.layers.iter().zip(spec.layer_kinds()).enumerate() {
            if layer.kind != kind {
                return Err(ModelError::ShapeMismatch(format!(
                    "layer {l} is {:?} but spec '{}' describes {:?}",
                    layer.kind, spec.name, kind
                )));
            }
        }
        let mut params = Vec::new();
        for layer in &self.layers {
            match layer.kind {
                LayerKind::Dense => {
                    let nm = layer.n * layer.m;
                    params.push(layer.params[..nm].to_vec());
                    params.push(layer.params[nm..].to_vec());
                }
                _ => params.push(layer.params.clone()),
            }
        }
        ModelBundle::new(spec.clone(), params)
    }
}

impl EmbedBag {
    /// Reconstruct the embedding table a bundle stores: identity from
    /// the spec, bucket array copied bit-exactly from the single tensor.
    pub fn from_bundle(bundle: &ModelBundle) -> Result<EmbedBag, ModelError> {
        bundle.check_shapes()?;
        let w = bundle.params.first().cloned().ok_or_else(|| {
            ModelError::ShapeMismatch("embedding bundle carries no tensor".into())
        })?;
        EmbedBag::from_spec(&bundle.spec, w)
    }

    /// Package the bucket array under `spec` — the inverse of
    /// [`EmbedBag::from_bundle`]. Fails when the spec does not describe
    /// this table.
    pub fn to_bundle(&self, spec: &ModelSpec) -> Result<ModelBundle, ModelError> {
        spec.validate()?;
        let Some((nc, dim, k, mode)) = spec.embedding_shape() else {
            return Err(ModelError::ShapeMismatch(format!(
                "spec '{}' does not describe a hashed_embedding",
                spec.name
            )));
        };
        if (nc, dim, k, mode, spec.seed_base)
            != (self.num_categories, self.dim, self.k(), self.mode, self.seed_base)
        {
            return Err(ModelError::ShapeMismatch(format!(
                "embedding table ({}x{}, k={}, {}, seed {:#010x}) does not match spec '{}'",
                self.num_categories,
                self.dim,
                self.k(),
                self.mode.as_str(),
                self.seed_base,
                spec.name
            )));
        }
        ModelBundle::new(spec.clone(), vec![self.w.clone()])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{BagMode, Method};
    use crate::util::rng::Pcg32;

    fn spec(method: Method) -> ModelSpec {
        ModelSpec::new("unit", method, vec![6, 5, 3], vec![14, 7], 0x9E37_79B9, 4).unwrap()
    }

    #[test]
    fn bytes_roundtrip_bit_exact() {
        let mut net = Network::from_spec(&spec(Method::Hashnet)).unwrap();
        net.init(&mut Pcg32::new(5, 5));
        let bundle = net.to_bundle(&spec(Method::Hashnet)).unwrap();
        let back = ModelBundle::from_bytes(&bundle.to_bytes()).unwrap();
        assert_eq!(back.spec, bundle.spec);
        assert_eq!(back.params, bundle.params);
        assert_eq!(back.version, BUNDLE_VERSION);
    }

    #[test]
    fn dense_split_layout_matches_state_convention() {
        let s = spec(Method::Nn);
        let mut net = Network::from_spec(&s).unwrap();
        net.init(&mut Pcg32::new(7, 7));
        let b = net.to_bundle(&s).unwrap();
        // [W0 (5*6), b0 (5), W1 (3*5), b1 (3)]
        assert_eq!(
            b.params.iter().map(Vec::len).collect::<Vec<_>>(),
            vec![30, 5, 15, 3]
        );
        let back = Network::from_bundle(&b).unwrap();
        assert_eq!(back.layers[0].params, net.layers[0].params);
        assert_eq!(back.layers[1].params, net.layers[1].params);
    }

    #[test]
    fn to_bundle_rejects_wrong_spec() {
        let mut net = Network::from_spec(&spec(Method::Hashnet)).unwrap();
        net.init(&mut Pcg32::new(1, 1));
        // wrong kind
        assert!(matches!(
            net.to_bundle(&spec(Method::Nn)),
            Err(ModelError::ShapeMismatch(_))
        ));
        // wrong dims
        let other =
            ModelSpec::new("o", Method::Hashnet, vec![6, 4, 3], vec![14, 7], 1, 4).unwrap();
        assert!(matches!(
            net.to_bundle(&other),
            Err(ModelError::ShapeMismatch(_))
        ));
    }

    #[test]
    fn embedding_bundle_roundtrip_bit_exact() {
        let s = ModelSpec::embedding("bag", 1_000, 8, 64, BagMode::Mean, 0x9E37_79B9, 4).unwrap();
        let mut bag = EmbedBag::new(1_000, 8, 64, BagMode::Mean, 0x9E37_79B9);
        bag.init(&mut Pcg32::new(3, 3));
        let bundle = bag.to_bundle(&s).unwrap();
        assert_eq!(bundle.n_params(), 64); // K buckets only, never nc*dim
        let back = ModelBundle::from_bytes(&bundle.to_bytes()).unwrap();
        let served = EmbedBag::from_bundle(&back).unwrap();
        assert_eq!(served.w, bag.w);
        assert_eq!(served.mode, BagMode::Mean);
        // the feed-forward loader refuses the same bundle with a typed
        // error instead of tripping the from_dims assert
        assert!(matches!(
            Network::from_bundle(&back),
            Err(ModelError::InvalidSpec(_))
        ));
        // and the embedding loader refuses feed-forward bundles
        let dense = spec(Method::Hashnet);
        let mut net = Network::from_spec(&dense).unwrap();
        net.init(&mut Pcg32::new(1, 1));
        let nb = net.to_bundle(&dense).unwrap();
        assert!(EmbedBag::from_bundle(&nb).is_err());
    }

    #[test]
    fn new_validates_param_layout() {
        let s = spec(Method::Hashnet);
        assert!(ModelBundle::new(s.clone(), vec![vec![0.0; 14], vec![0.0; 7]]).is_ok());
        assert!(matches!(
            ModelBundle::new(s, vec![vec![0.0; 13], vec![0.0; 7]]),
            Err(ModelError::ShapeMismatch(_))
        ));
    }
}
