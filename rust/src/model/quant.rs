//! Per-tensor quantization codecs for HNMB v2 bundles.
//!
//! The paper stops at f32 bucket values; Deep Compression (Han et al.,
//! see PAPERS.md) shows that quantizing the *shared* weights stacks
//! another 4–8× on top of hash compression. This module provides the
//! three codecs a v2 bundle section can carry:
//!
//! * **f32** (tag 0) — passthrough, `n × f32 LE`. The only codec the
//!   zero-copy serve path can borrow in place.
//! * **int8** (tag 1) — affine per-tensor quantization: `min: f32`,
//!   `scale: f32`, then `n × u8` codes. `v̂ = min + code · scale`,
//!   `scale = (max − min)/255`, so the absolute round-trip error is
//!   bounded by `scale/2`.
//! * **codebook** (tag 2) — 1-D k-means shared-value table (≤ 256
//!   entries, Deep Compression's weight-sharing stage): `table_len:
//!   u32`, `table_len × f32`, then `n × u8` indices. Exact whenever the
//!   tensor holds ≤ 256 distinct values — which a K-bucket HashedNet
//!   layer often does after aggressive compression.
//!
//! An [`Encoding`] stores the codec *and* the encoded codes; the
//! decoded values always live in `ModelBundle::params`. Keeping the
//! codes (rather than re-encoding on save) is what makes
//! `save → load → save` byte-exact for every codec: no float-rounding
//! round trip can perturb the stored bytes.

use super::ModelError;

/// Section-table codec tags (the on-disk `codec` field of a v2 bundle).
pub const CODEC_F32: u32 = 0;
pub const CODEC_INT8: u32 = 1;
pub const CODEC_CODEBOOK: u32 = 2;

/// Hard cap on codebook entries: indices must fit one byte.
pub const MAX_CODEBOOK: usize = 256;

/// Lloyd iterations for the 1-D k-means fit. Deterministic (quantile
/// init, no RNG), so the same tensor always yields the same table.
const KMEANS_ITERS: usize = 25;

/// How one tensor is stored on disk. The dequantized values live in
/// `ModelBundle::params`; this carries the codec parameters and (for
/// the lossy codecs) the authoritative encoded codes.
#[derive(Debug, Clone, PartialEq)]
pub enum Encoding {
    /// Plain `f32` payload — serialized from the decoded params.
    F32,
    /// Affine int8: `v̂ = min + code · scale`.
    Int8 { min: f32, scale: f32, codes: Vec<u8> },
    /// Shared-value table (sorted, deduplicated) + one index per value.
    Codebook { table: Vec<f32>, codes: Vec<u8> },
}

impl Encoding {
    /// The on-disk codec tag.
    pub fn codec_tag(&self) -> u32 {
        match self {
            Encoding::F32 => CODEC_F32,
            Encoding::Int8 { .. } => CODEC_INT8,
            Encoding::Codebook { .. } => CODEC_CODEBOOK,
        }
    }

    /// Human-readable codec name (CLI tables, `list` output).
    pub fn codec_name(&self) -> &'static str {
        match self {
            Encoding::F32 => "f32",
            Encoding::Int8 { .. } => "int8",
            Encoding::Codebook { .. } => "codebook",
        }
    }

    /// Encoded payload length in bytes for a tensor of `n_elems`
    /// logical f32 values.
    pub fn encoded_len(&self, n_elems: usize) -> usize {
        match self {
            Encoding::F32 => 4 * n_elems,
            Encoding::Int8 { .. } => 8 + n_elems,
            Encoding::Codebook { table, .. } => 4 + 4 * table.len() + n_elems,
        }
    }

    /// Number of logical elements the stored codes describe (== the
    /// decoded tensor length; for `F32` the data lives in `params`, so
    /// there is nothing to report here).
    pub fn code_len(&self) -> Option<usize> {
        match self {
            Encoding::F32 => None,
            Encoding::Int8 { codes, .. } | Encoding::Codebook { codes, .. } => Some(codes.len()),
        }
    }

    /// Dequantize the stored codes. `None` for `F32` (decoded values
    /// are the payload itself).
    pub fn decode(&self) -> Option<Vec<f32>> {
        match self {
            Encoding::F32 => None,
            Encoding::Int8 { min, scale, codes } => Some(decode_int8(*min, *scale, codes)),
            Encoding::Codebook { table, codes } => Some(decode_codebook(table, codes)),
        }
    }
}

/// The user-facing quantization request (`--quantize int8|codebook{K}`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QuantSpec {
    F32,
    Int8,
    /// k-means with at most `K` table entries (1..=256).
    Codebook(usize),
}

impl QuantSpec {
    /// Parse a CLI codec string: `f32`, `int8`, `codebook` (= 256
    /// entries) or `codebook{K}` e.g. `codebook64`.
    pub fn parse(s: &str) -> Result<QuantSpec, ModelError> {
        match s {
            "f32" => return Ok(QuantSpec::F32),
            "int8" => return Ok(QuantSpec::Int8),
            "codebook" => return Ok(QuantSpec::Codebook(MAX_CODEBOOK)),
            _ => {}
        }
        if let Some(k) = s.strip_prefix("codebook") {
            let k: usize = k.parse().map_err(|_| {
                ModelError::InvalidSpec(format!("bad codebook size in --quantize {s}"))
            })?;
            if k == 0 || k > MAX_CODEBOOK {
                return Err(ModelError::InvalidSpec(format!(
                    "codebook size must be 1..={MAX_CODEBOOK}, got {k}"
                )));
            }
            return Ok(QuantSpec::Codebook(k));
        }
        Err(ModelError::InvalidSpec(format!(
            "unknown codec '{s}' (expected f32, int8 or codebook{{K}})"
        )))
    }

    pub fn name(&self) -> String {
        match self {
            QuantSpec::F32 => "f32".into(),
            QuantSpec::Int8 => "int8".into(),
            QuantSpec::Codebook(k) => format!("codebook{k}"),
        }
    }
}

/// Quantize one tensor: returns the encoding and the dequantized
/// values (what predictions will actually use — "quantization-aware"
/// by construction).
pub fn quantize_tensor(v: &[f32], spec: QuantSpec) -> (Encoding, Vec<f32>) {
    match spec {
        QuantSpec::F32 => (Encoding::F32, v.to_vec()),
        QuantSpec::Int8 => {
            let (min, scale, codes) = encode_int8(v);
            let decoded = decode_int8(min, scale, &codes);
            (Encoding::Int8 { min, scale, codes }, decoded)
        }
        QuantSpec::Codebook(k) => {
            let table = fit_codebook(v, k);
            let codes = encode_codebook(&table, v);
            let decoded = decode_codebook(&table, &codes);
            (Encoding::Codebook { table, codes }, decoded)
        }
    }
}

/// Affine int8 encode: `scale = (max − min)/255`, codes round to the
/// nearest step. Degenerate tensors (constant, empty, or no finite
/// values) get `scale = 0` and all-zero codes, which decode back to
/// `min` exactly.
pub fn encode_int8(v: &[f32]) -> (f32, f32, Vec<u8>) {
    let mut min = f32::INFINITY;
    let mut max = f32::NEG_INFINITY;
    for &x in v {
        if x.is_finite() {
            min = min.min(x);
            max = max.max(x);
        }
    }
    if !min.is_finite() || !max.is_finite() {
        return (0.0, 0.0, vec![0; v.len()]);
    }
    let scale = (max - min) / 255.0;
    let codes = if scale > 0.0 {
        // NaN/inf inputs fall out as saturating casts (0 or 255), never
        // a panic
        v.iter().map(|&x| (((x - min) / scale).round()).clamp(0.0, 255.0) as u8).collect()
    } else {
        vec![0; v.len()]
    };
    (min, scale, codes)
}

pub fn decode_int8(min: f32, scale: f32, codes: &[u8]) -> Vec<f32> {
    codes.iter().map(|&q| min + q as f32 * scale).collect()
}

/// Deterministic 1-D k-means: quantile init over the sorted values,
/// fixed Lloyd iterations, then sort + exact-dedup. When the tensor has
/// ≤ `k` distinct values the table is exactly those values, so the
/// codec is lossless in that regime.
pub fn fit_codebook(v: &[f32], k: usize) -> Vec<f32> {
    let k = k.clamp(1, MAX_CODEBOOK);
    let mut sorted: Vec<f32> = v.iter().copied().filter(|x| x.is_finite()).collect();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    sorted.dedup();
    if sorted.is_empty() {
        return vec![0.0];
    }
    if sorted.len() <= k {
        return sorted;
    }
    // quantile init: spread the k centroids over the value range
    let mut centroids: Vec<f32> =
        (0..k).map(|i| sorted[i * (sorted.len() - 1) / (k - 1).max(1)]).collect();
    centroids.dedup();
    // weights: Lloyd's must see duplicates, so run over the raw finite
    // values, not the deduped support
    let mut values: Vec<f32> = v.iter().copied().filter(|x| x.is_finite()).collect();
    values.sort_by(|a, b| a.partial_cmp(b).unwrap());
    for _ in 0..KMEANS_ITERS {
        // assignment boundaries are the midpoints between consecutive
        // centroids (centroids stay sorted through the iteration)
        let mut sums = vec![0.0f64; centroids.len()];
        let mut counts = vec![0usize; centroids.len()];
        let mut c = 0;
        for &x in &values {
            while c + 1 < centroids.len() && (centroids[c] + centroids[c + 1]) / 2.0 < x {
                c += 1;
            }
            sums[c] += x as f64;
            counts[c] += 1;
        }
        let mut moved = false;
        for i in 0..centroids.len() {
            if counts[i] > 0 {
                let m = (sums[i] / counts[i] as f64) as f32;
                if m != centroids[i] {
                    centroids[i] = m;
                    moved = true;
                }
            }
        }
        centroids.sort_by(|a, b| a.partial_cmp(b).unwrap());
        if !moved {
            break;
        }
    }
    centroids.dedup();
    centroids
}

/// Index of the nearest table entry (table sorted ascending, deduped).
/// Ties break toward the lower entry; non-finite values map to entry 0.
/// Exact table entries always map to themselves, which is what makes
/// `encode(decode(codes)) == codes`.
fn nearest(table: &[f32], v: f32) -> u8 {
    let i = table.partition_point(|&t| t < v);
    if i == 0 {
        return 0;
    }
    if i >= table.len() {
        return (table.len() - 1) as u8;
    }
    if v - table[i - 1] <= table[i] - v {
        (i - 1) as u8
    } else {
        i as u8
    }
}

pub fn encode_codebook(table: &[f32], v: &[f32]) -> Vec<u8> {
    v.iter().map(|&x| nearest(table, x)).collect()
}

pub fn decode_codebook(table: &[f32], codes: &[u8]) -> Vec<f32> {
    // table never empty (fit_codebook returns ≥1 entry; the bundle
    // parser rejects table_len == 0), and the parser/encoder bound
    // every code < table_len ≤ 256 — but index defensively anyway
    codes.iter().map(|&c| table.get(c as usize).copied().unwrap_or(0.0)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn random_values(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Pcg32::new(seed, 0x9A17);
        let mut v = vec![0.0f32; n];
        rng.fill_normal(&mut v, 0.5);
        v
    }

    #[test]
    fn int8_roundtrip_error_bounded_by_half_step() {
        let v = random_values(4096, 11);
        let (min, scale, codes) = encode_int8(&v);
        let back = decode_int8(min, scale, &codes);
        assert!(scale > 0.0);
        for (a, b) in v.iter().zip(&back) {
            // the satellite bound: max abs error ≤ scale/2 (tiny fp
            // slack for the decode arithmetic itself)
            assert!(
                (a - b).abs() as f64 <= scale as f64 * 0.5 * (1.0 + 1e-5) + 1e-12,
                "|{a} - {b}| > scale/2 = {}",
                scale / 2.0
            );
        }
    }

    #[test]
    fn int8_degenerate_constant_tensor_is_exact() {
        let v = vec![0.25f32; 17];
        let (min, scale, codes) = encode_int8(&v);
        assert_eq!((min, scale), (0.25, 0.0));
        assert!(codes.iter().all(|&c| c == 0));
        assert_eq!(decode_int8(min, scale, &codes), v);
    }

    #[test]
    fn codebook_exact_when_distinct_fits() {
        // 200 distinct values, each repeated — fits a 256-entry table
        let mut v = Vec::new();
        for i in 0..200 {
            let x = (i as f32) * 0.125 - 12.5;
            v.extend_from_slice(&[x, x, x]);
        }
        let table = fit_codebook(&v, 256);
        assert_eq!(table.len(), 200);
        let codes = encode_codebook(&table, &v);
        assert_eq!(decode_codebook(&table, &codes), v, "≤256 distinct values must be lossless");
    }

    #[test]
    fn codebook_reencode_is_idempotent() {
        let v = random_values(2048, 23);
        let table = fit_codebook(&v, 64);
        assert!(table.len() <= 64 && !table.is_empty());
        assert!(table.windows(2).all(|w| w[0] < w[1]), "table sorted + deduped");
        let codes = encode_codebook(&table, &v);
        let decoded = decode_codebook(&table, &codes);
        // decoded values are exact table entries: re-encoding them
        // reproduces the codes bit-for-bit (the save→load→save anchor)
        assert_eq!(encode_codebook(&table, &decoded), codes);
    }

    #[test]
    fn quantize_tensor_decoded_matches_encoding() {
        let v = random_values(512, 31);
        for spec in [QuantSpec::F32, QuantSpec::Int8, QuantSpec::Codebook(32)] {
            let (enc, decoded) = quantize_tensor(&v, spec);
            assert_eq!(decoded.len(), v.len());
            match enc.decode() {
                None => assert_eq!(decoded, v),
                Some(d) => assert_eq!(d, decoded),
            }
        }
    }

    #[test]
    fn quant_spec_parses_cli_forms() {
        assert_eq!(QuantSpec::parse("int8").unwrap(), QuantSpec::Int8);
        assert_eq!(QuantSpec::parse("codebook").unwrap(), QuantSpec::Codebook(256));
        assert_eq!(QuantSpec::parse("codebook16").unwrap(), QuantSpec::Codebook(16));
        assert!(QuantSpec::parse("codebook0").is_err());
        assert!(QuantSpec::parse("codebook999").is_err());
        assert!(QuantSpec::parse("int4").is_err());
    }

    #[test]
    fn hostile_inputs_never_panic() {
        let weird = vec![f32::NAN, f32::INFINITY, f32::NEG_INFINITY, 1.0, -1.0];
        let (min, scale, codes) = encode_int8(&weird);
        assert_eq!(codes.len(), weird.len());
        let _ = decode_int8(min, scale, &codes);
        let table = fit_codebook(&weird, 8);
        let codes = encode_codebook(&table, &weird);
        let _ = decode_codebook(&table, &codes);
        let all_nan = vec![f32::NAN; 4];
        let (_, s, c) = encode_int8(&all_nan);
        assert_eq!((s, c.len()), (0.0, 4));
        assert_eq!(fit_codebook(&all_nan, 4), vec![0.0]);
        assert_eq!(fit_codebook(&[], 4), vec![0.0]);
    }
}
