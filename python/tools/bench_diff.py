"""Compare fresh ``BENCH_*.json`` bench reports against committed
baselines with per-metric tolerance bands (ROADMAP item: track the
perf trajectory across PRs instead of eyeballing JSON).

Usage (normally via ``make bench-diff``)::

    python -m tools.bench_diff --fresh <repo-root> --baselines benches/baselines
    python -m tools.bench_diff ... --strict          # exit 1 on regression
    python -m tools.bench_diff ... --tolerance 0.5   # override the band

Two report schemas exist in this repo and both are handled:

* the ``util::bench`` array schema — a JSON array of cases, each with
  ``name`` plus numeric metrics (``mean_ns``/``p50_ns``/…/``throughput``);
* the ``serve_scale``/``kernel_forward`` object schema — a top-level
  object whose ``cases`` array carries ``name`` + numeric metrics, plus
  top-level numeric metadata (which is compared too, at an exact-match
  band of "informational only"). ``kernel_forward`` records ``avx2``
  0/1 and the layer shape as metadata, and a per-case ``gflops``
  compute-throughput metric for the kernel-grid rows.

Cases are matched by their ``name`` field; metrics are compared
relatively: latency-like metrics (``*_ns``/``*_us``/``*_ms``/``*_s``,
``mean``/``p50``/``p95``/``p99``) regress when the fresh value is
*higher* than baseline × (1 + tol); throughput-like metrics
(``throughput*``, ``*_rps``) regress when the fresh value is *lower*
than baseline × (1 - tol). Everything else (iters, counts, flags) is
reported when it drifts but never gates.

Benches are inherently machine-relative, so the default band is wide
(35 %) and the exit code is 0 unless ``--strict`` is passed. A fresh
report with no committed baseline (or vice versa) is reported and
skipped — never an error — so the tool works before any baseline has
been recorded.
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import sys

DEFAULT_TOLERANCE = 0.35

#: metric-name suffixes treated as "lower is better"
_LATENCY_KEYS = ("_ns", "_us", "_ms", "_s")
_LATENCY_NAMES = ("mean", "p50", "p95", "p99", "stddev", "wall")
#: metric-name markers treated as "higher is better"
_THROUGHPUT_MARKERS = ("throughput", "_rps", "req_s", "gflops")


def metric_kind(key: str) -> str:
    """Classify a metric name: 'latency', 'throughput', or 'info'."""
    k = key.lower()
    if any(m in k for m in _THROUGHPUT_MARKERS):
        return "throughput"
    if k.endswith(_LATENCY_KEYS) or any(k.startswith(n) for n in _LATENCY_NAMES):
        return "latency"
    return "info"


def load_cases(path: str):
    """Load one report as ``(cases, meta)``.

    ``cases`` maps case name → {metric: number}; ``meta`` holds
    top-level numeric fields of object-schema reports.
    """
    with open(path) as f:
        doc = json.load(f)
    if isinstance(doc, list):
        raw_cases, meta = doc, {}
    elif isinstance(doc, dict):
        raw_cases = doc.get("cases", [])
        meta = {
            k: v
            for k, v in doc.items()
            if isinstance(v, (int, float)) and not isinstance(v, bool)
        }
    else:
        raise ValueError(f"{path}: expected a JSON array or object")
    cases = {}
    for i, case in enumerate(raw_cases):
        if not isinstance(case, dict):
            continue
        name = str(case.get("name", f"case[{i}]"))
        cases[name] = {
            k: float(v)
            for k, v in case.items()
            if isinstance(v, (int, float)) and not isinstance(v, bool)
        }
    return cases, meta


class Diff:
    """Accumulates comparisons; knows whether anything regressed."""

    def __init__(self, tolerance: float):
        self.tolerance = tolerance
        self.lines: list[str] = []
        self.regressions: list[str] = []

    def compare_metric(self, ctx: str, key: str, base: float, fresh: float) -> None:
        kind = metric_kind(key)
        if base == 0.0:
            # can't form a ratio; report drift only
            if fresh != base:
                self.lines.append(f"  ~ {ctx}.{key}: {base:g} -> {fresh:g} (no ratio)")
            return
        rel = (fresh - base) / abs(base)
        marker, regressed = "  ", False
        if kind == "latency" and rel > self.tolerance:
            marker, regressed = "✗ ", True
        elif kind == "throughput" and rel < -self.tolerance:
            marker, regressed = "✗ ", True
        elif kind != "info" and abs(rel) > self.tolerance:
            marker = "✓ "  # outside the band in the *good* direction
        if marker != "  " or kind == "info" and abs(rel) > self.tolerance:
            self.lines.append(
                f"  {marker}{ctx}.{key}: {base:g} -> {fresh:g} ({rel:+.1%})"
            )
        if regressed:
            self.regressions.append(f"{ctx}.{key}: {base:g} -> {fresh:g} ({rel:+.1%})")

    def compare_report(self, name: str, base_path: str, fresh_path: str) -> None:
        base_cases, base_meta = load_cases(base_path)
        fresh_cases, fresh_meta = load_cases(fresh_path)
        self.lines.append(f"{name}:")
        for key in sorted(set(base_meta) & set(fresh_meta)):
            self.compare_metric(name, key, base_meta[key], fresh_meta[key])
        for case in sorted(set(base_cases) | set(fresh_cases)):
            if case not in fresh_cases:
                self.lines.append(f"  ~ {name}[{case}]: in baseline only (case removed?)")
                continue
            if case not in base_cases:
                self.lines.append(f"  ~ {name}[{case}]: new case (no baseline)")
                continue
            b, f = base_cases[case], fresh_cases[case]
            for key in sorted(set(b) & set(f)):
                self.compare_metric(f"{name}[{case}]", key, b[key], f[key])


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="bench_diff",
        description="diff fresh BENCH_*.json against committed baselines",
    )
    ap.add_argument("--fresh", default=".", help="directory holding fresh BENCH_*.json")
    ap.add_argument(
        "--baselines",
        default="benches/baselines",
        help="directory holding committed baseline BENCH_*.json",
    )
    ap.add_argument(
        "--tolerance",
        type=float,
        default=DEFAULT_TOLERANCE,
        help=f"relative tolerance band (default {DEFAULT_TOLERANCE})",
    )
    ap.add_argument(
        "--strict",
        action="store_true",
        help="exit 1 when any metric regresses past the band",
    )
    args = ap.parse_args(argv)

    fresh = {
        os.path.basename(p): p
        for p in glob.glob(os.path.join(args.fresh, "BENCH_*.json"))
    }
    base = {
        os.path.basename(p): p
        for p in glob.glob(os.path.join(args.baselines, "BENCH_*.json"))
    }

    if not fresh:
        print(f"no fresh BENCH_*.json under {args.fresh} — run `make bench` first")
        return 0
    diff = Diff(args.tolerance)
    compared = 0
    for name in sorted(set(fresh) | set(base)):
        if name not in base:
            print(f"{name}: fresh report has no committed baseline (skipped) — "
                  f"record one under {args.baselines}/ to start tracking it")
            continue
        if name not in fresh:
            print(f"{name}: baseline exists but no fresh report produced (skipped)")
            continue
        try:
            diff.compare_report(name, base[name], fresh[name])
            compared += 1
        except (ValueError, json.JSONDecodeError) as e:
            print(f"{name}: unreadable ({e}); skipped")

    for line in diff.lines:
        print(line)
    print(
        f"compared {compared} report(s) at ±{args.tolerance:.0%}: "
        f"{len(diff.regressions)} regression(s)"
    )
    for r in diff.regressions:
        print(f"  REGRESSION {r}")
    if diff.regressions and args.strict:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
