"""Repo tooling that is neither the compile path nor the test suite.

Currently: ``bench_diff`` — compare fresh ``BENCH_*.json`` bench
reports against the committed baselines in ``benches/baselines/`` with
per-metric tolerance bands (``make bench-diff``).
"""
