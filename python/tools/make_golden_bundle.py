"""Generate the committed HNMB **v1** golden fixtures under
``rust/tests/data/`` — from Python, independently of the Rust writer.

The point of a golden file is to pin the *format*, not the writer: if
the fixture were produced by ``ModelBundle::to_bytes_v1`` it would
silently track any Rust serialization bug. Instead this script builds
the v1 byte layout by hand (and a legacy ``HNCK`` checkpoint with the
same tensors) using the Python xxh32 reference implementation that the
Rust hash tests already cross-check against.

Layout written (v1, as documented in ``rust/src/model/bundle.rs``)::

    "HNMB" | version=1 u32 LE | spec_len u32 LE | spec JSON |
    n_tens u32 LE | per tensor: len u32 LE + len x f32 LE |
    xxh32(all preceding bytes, seed 0x4D42) u32 LE

    "HNCK" | n_tens u32 LE | per tensor: len u32 LE + len x f32 LE

Model: hashnet, dims [6,5,4], budgets [10,8] — tensor ``t`` element
``i`` holds ``((t*31 + i*7) % 13) * 0.125 - 0.75`` (eighths: exactly
representable in f32, so the fixture is bit-stable across platforms).

Usage::

    cd python && python -m tools.make_golden_bundle
"""

from __future__ import annotations

import os
import struct

from compile.hashing import xxh32

CHECKSUM_SEED = 0x4D42  # "MB"

SPEC_JSON = (
    '{"name":"golden_v1","method":"hashnet","dims":[6,5,4],'
    '"budgets":[10,8],"seed_base":2654435769,"batch":4}'
)
TENSOR_LENS = [10, 8]  # hashnet: one K-budget tensor per layer


def tensor_values(t: int, n: int) -> list[float]:
    return [((t * 31 + i * 7) % 13) * 0.125 - 0.75 for i in range(n)]


def v1_bundle_bytes() -> bytes:
    body = b"HNMB"
    body += struct.pack("<I", 1)
    body += struct.pack("<I", len(SPEC_JSON))
    body += SPEC_JSON.encode()
    body += struct.pack("<I", len(TENSOR_LENS))
    for t, n in enumerate(TENSOR_LENS):
        body += struct.pack("<I", n)
        body += struct.pack(f"<{n}f", *tensor_values(t, n))
    return body + struct.pack("<I", xxh32(body, CHECKSUM_SEED))


def hnck_bytes() -> bytes:
    body = b"HNCK"
    body += struct.pack("<I", len(TENSOR_LENS))
    for t, n in enumerate(TENSOR_LENS):
        body += struct.pack("<I", n)
        body += struct.pack(f"<{n}f", *tensor_values(t, n))
    return body


def main() -> None:
    out_dir = os.path.join(os.path.dirname(__file__), "..", "..", "rust", "tests", "data")
    os.makedirs(out_dir, exist_ok=True)
    for name, data in [("golden_v1.hnb", v1_bundle_bytes()), ("golden_v1.ckpt", hnck_bytes())]:
        path = os.path.join(out_dir, name)
        with open(path, "wb") as f:
            f.write(data)
        print(f"wrote {os.path.normpath(path)} ({len(data)} B)")


if __name__ == "__main__":
    main()
