"""Layer-1 Pallas kernels and their pure-jnp oracles."""

from .hashed_matmul import make_hashed_matmul  # noqa: F401
