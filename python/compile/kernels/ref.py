"""Pure-jnp correctness oracle for the hashed layer (Eqs. 3–7 of the paper).

The oracle materializes the full virtual matrix

    V_ij = xi(i, j) * w_{h(i, j)}            (Eq. 7)

and computes ``z = a @ V.T`` (Eq. 4).  It is differentiable by plain JAX
autodiff, which gives us reference gradients for the custom-VJP Pallas
path *and* doubles as the feature-hashing interpretation check (Eq. 5):
``z_i = w^T phi_i(a)`` where ``[phi_i(a)]_k = sum_{j: h(i,j)=k} xi(i,j) a_j``.
"""

from __future__ import annotations

import jax.numpy as jnp

from ..hashing import hash_grid


def virtual_matrix(w, M: int, N: int, K: int, seed_h: int, seed_xi: int):
    """Decompress the virtual weight matrix V in R^{N x M} from w in R^K."""
    ids, signs = hash_grid(M, N, K, seed_h, seed_xi, xp=jnp)
    return w[ids] * signs


def hashed_matmul_ref(a, w, N: int, K: int, seed_h: int, seed_xi: int):
    """z[B, N] = a[B, M] @ V[N, M].T with hash-decompressed V (Eq. 4)."""
    M = a.shape[-1]
    V = virtual_matrix(w, M, N, K, seed_h, seed_xi)
    return jnp.dot(a, V.T)


def feature_hash_ref(a, w, N: int, K: int, seed_h: int, seed_xi: int):
    """The feature-hashing interpretation (Eq. 5–6): z_i = w^T phi_i(a).

    Mathematically identical to :func:`hashed_matmul_ref` (§4.3); kept as
    an independent code path for the equivalence test.
    """
    M = a.shape[-1]
    ids, signs = hash_grid(M, N, K, seed_h, seed_xi, xp=jnp)
    onehot = (ids[..., None] == jnp.arange(K, dtype=jnp.uint32)[None, None, :]).astype(
        a.dtype
    )
    # [phi_i(a)]_k = sum_j xi(i,j) a_j [h(i,j) = k]
    phi = jnp.einsum("bj,ijk->bik", a, onehot * signs[..., None])
    return jnp.einsum("bik,k->bi", phi, w)
