"""Layer-1 Pallas kernel: decompress-on-the-fly hashed matmul.

The HashedNets hot-spot is ``z = a @ V.T`` where the virtual matrix
``V_ij = xi(i,j) * w_{h(i,j)}`` (paper Eq. 7) is never materialized in
HBM.  Each grid step

  1. hashes a ``(bn, bm)`` tile of the global index grid with xxh32
     (vector-unit integer ops),
  2. gathers the shared weights ``w`` — which live wholly in VMEM —
     and applies the sign hash, producing the tile of ``V`` in VMEM,
  3. feeds an MXU-shaped ``a_tile @ V_tile.T`` accumulation.

HBM traffic is therefore ``a + z + w`` — the *compressed* footprint.
This is the TPU re-think of the paper's GPU "non-coalesced gather"
worry (§7): the gather is VMEM-local and the contraction stays a plain
matmul (DESIGN.md §Hardware-Adaptation).

Backward is a ``jax.custom_vjp``:

  * ``da = delta @ V``    — second Pallas kernel regenerating the same
    tiles with the transposed contraction,
  * ``dw_k = sum_{ij: h(i,j)=k} xi(i,j) a_j delta_i``  (paper Eq. 12)
    — an XLA ``segment_sum`` over the hash buckets (scatter-add); the
    MXU-friendly one-hot-matmul variant is discussed in DESIGN.md.

Kernels are lowered with ``interpret=True``: CPU PJRT cannot execute
Mosaic custom-calls, and interpret mode traces to plain HLO that XLA
compiles like any other op.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..hashing import hash_grid, xxh32_u32


def _cdiv(a: int, b: int) -> int:
    return -(-a // b)


@dataclass(frozen=True)
class HashedLayerSpec:
    """Static configuration of one hashed layer (shapes + hash seeds)."""

    M: int  # fan-in  (incl. bias column if the caller augments)
    N: int  # fan-out
    K: int  # number of real (shared) weights — the memory budget
    seed_h: int  # bucket hash seed  (h^l)
    seed_xi: int  # sign hash seed   (xi^l)
    block_n: int = 128
    block_m: int = 256
    # ablation switch: drop the collision-debiasing sign factor xi(i,j)
    # (paper 4.3) so V_ij = w_{h(i,j)} only
    use_sign: bool = True

    @property
    def compression(self) -> float:
        return self.K / float(self.M * self.N)


def _tile_virtual(spec: HashedLayerSpec, w, n_idx, m_idx, bn: int, bm: int):
    """Generate one (bn, bm) tile of V = sign * w[h] inside the kernel.

    ``w`` is the full weight vector value (already loaded from VMEM).
    Out-of-range (i >= N or j >= M) entries are zeroed so padded tiles
    contribute nothing to the contraction.
    """
    i = (n_idx * bn + jax.lax.broadcasted_iota(jnp.uint32, (bn, bm), 0))
    j = (m_idx * bm + jax.lax.broadcasted_iota(jnp.uint32, (bn, bm), 1))
    keys = i * jnp.uint32(spec.M) + j
    h = xxh32_u32(keys, spec.seed_h, xp=jnp)
    ids = h % jnp.uint32(spec.K)
    valid = (i < jnp.uint32(spec.N)) & (j < jnp.uint32(spec.M))
    if spec.use_sign:
        sign = jnp.float32(1.0) - jnp.float32(2.0) * (
            xxh32_u32(keys, spec.seed_xi, xp=jnp) & jnp.uint32(1)
        ).astype(jnp.float32)
        tile = w[ids] * sign
    else:
        tile = w[ids]
    return jnp.where(valid, tile, jnp.float32(0.0))


def _fwd_kernel(a_ref, w_ref, o_ref, *, spec: HashedLayerSpec, bn: int, bm: int):
    """o[B, bn] += a[B, bm] @ V_tile[bn, bm].T  (grid = (nN, nM))."""
    m_idx = pl.program_id(1)

    @pl.when(m_idx == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    tile = _tile_virtual(spec, w_ref[...], pl.program_id(0), m_idx, bn, bm)
    # Padded tail blocks contain uninitialized data; 0 * garbage (or NaN)
    # would poison the accumulation, so mask the activation columns too.
    j = m_idx * bm + jax.lax.broadcasted_iota(jnp.uint32, (1, bm), 1)
    a = jnp.where(j < jnp.uint32(spec.M), a_ref[...].astype(jnp.float32), 0.0)
    o_ref[...] += jax.lax.dot_general(
        a, tile, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )


def _bwd_da_kernel(g_ref, w_ref, o_ref, *, spec: HashedLayerSpec, bn: int, bm: int):
    """da[B, bm] += g[B, bn] @ V_tile[bn, bm]  (grid = (nM, nN))."""
    n_idx = pl.program_id(1)

    @pl.when(n_idx == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    tile = _tile_virtual(spec, w_ref[...], n_idx, pl.program_id(0), bn, bm)
    i = n_idx * bn + jax.lax.broadcasted_iota(jnp.uint32, (1, bn), 1)
    g = jnp.where(i < jnp.uint32(spec.N), g_ref[...].astype(jnp.float32), 0.0)
    o_ref[...] += jax.lax.dot_general(
        g, tile, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )


def _pallas_fwd(spec: HashedLayerSpec, a, w, interpret: bool):
    B, M = a.shape
    assert M == spec.M, f"fan-in mismatch: a has {M}, spec has {spec.M}"
    bn = min(spec.block_n, spec.N)
    bm = min(spec.block_m, spec.M)
    grid = (_cdiv(spec.N, bn), _cdiv(spec.M, bm))
    return pl.pallas_call(
        functools.partial(_fwd_kernel, spec=spec, bn=bn, bm=bm),
        grid=grid,
        in_specs=[
            pl.BlockSpec((B, bm), lambda n, m: (0, m)),
            pl.BlockSpec((spec.K,), lambda n, m: (0,)),
        ],
        out_specs=pl.BlockSpec((B, bn), lambda n, m: (0, n)),
        out_shape=jax.ShapeDtypeStruct((B, spec.N), jnp.float32),
        interpret=interpret,
    )(a, w)


def _pallas_bwd_da(spec: HashedLayerSpec, g, w, interpret: bool):
    B, N = g.shape
    assert N == spec.N
    bn = min(spec.block_n, spec.N)
    bm = min(spec.block_m, spec.M)
    grid = (_cdiv(spec.M, bm), _cdiv(spec.N, bn))
    return pl.pallas_call(
        functools.partial(_bwd_da_kernel, spec=spec, bn=bn, bm=bm),
        grid=grid,
        in_specs=[
            pl.BlockSpec((B, bn), lambda m, n: (0, n)),
            pl.BlockSpec((spec.K,), lambda m, n: (0,)),
        ],
        out_specs=pl.BlockSpec((B, bm), lambda m, n: (0, m)),
        out_shape=jax.ShapeDtypeStruct((B, spec.M), jnp.float32),
        interpret=interpret,
    )(g, w)


def _dw_segment_sum(spec: HashedLayerSpec, a, g):
    """dw via Eq. 12: bucket scatter-add of the (signed) outer product.

    ``G = g.T @ a`` is the dense gradient of the virtual matrix
    (dL/dV_ij = a_j * delta_i); dw_k sums G * xi over each hash bucket.
    """
    ids, signs = hash_grid(spec.M, spec.N, spec.K, spec.seed_h, spec.seed_xi, xp=jnp)
    if not spec.use_sign:
        signs = jnp.ones_like(signs)
    G = jax.lax.dot_general(
        g.astype(jnp.float32),
        a.astype(jnp.float32),
        (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (N, M)
    return jax.ops.segment_sum(
        (G * signs).reshape(-1), ids.reshape(-1).astype(jnp.int32), num_segments=spec.K
    )


def make_hashed_matmul(spec: HashedLayerSpec, interpret: bool = True):
    """Build the differentiable hashed matmul ``f(a[B,M], w[K]) -> z[B,N]``.

    Forward and ``da`` run as Pallas kernels; ``dw`` is an XLA
    segment-sum (see module docstring).  The returned function is
    traceable/jittable and AOT-lowers into the same HLO module as the
    surrounding model.
    """

    @jax.custom_vjp
    def hashed_matmul(a, w):
        return _pallas_fwd(spec, a, w, interpret)

    def fwd(a, w):
        return _pallas_fwd(spec, a, w, interpret), (a, w)

    def bwd(res, g):
        a, w = res
        da = _pallas_bwd_da(spec, g, w, interpret)
        dw = _dw_segment_sum(spec, a, g)
        return da.astype(a.dtype), dw.astype(w.dtype)

    hashed_matmul.defvjp(fwd, bwd)
    return hashed_matmul
