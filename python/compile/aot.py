"""AOT pipeline: lower every experiment configuration to HLO text.

Emits ``artifacts/<name>.<train|predict>.hlo.txt`` plus
``artifacts/manifest.json`` describing each artifact's I/O signature, so
the Rust coordinator can initialize parameters, marshal literals and run
training/inference without ever importing Python.

HLO **text** (not ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProtos with 64-bit instruction ids which xla_extension
0.5.1 (the version the published ``xla`` crate binds) rejects; the text
parser reassigns ids and round-trips cleanly.

Config sets
-----------
* ``core``   — a handful of small configs for tests/quickstart/serving.
* ``repro``  — the full experiment grid behind Figures 2–4 and Tables 1–2
  (6 methods x {3,5} layers x 7 compression factors x {10,2} classes,
  plus the Fig. 4 expansion sweep).  Scaled to this CPU testbed by
  ``--hidden`` (default 100; pass 1000 for paper scale).

Usage: ``python -m compile.aot --out-dir ../artifacts --set core,repro``
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from concurrent.futures import ProcessPoolExecutor
from dataclasses import asdict
from fractions import Fraction

import jax

from . import sizing
from .model import NetSpec, example_args, make_predict, make_train_step

METHODS = ["hashnet", "hashnet_dk", "nn", "dk", "rer", "lrd"]
COMPRESSIONS = [
    Fraction(1, 1), Fraction(1, 2), Fraction(1, 4), Fraction(1, 8),
    Fraction(1, 16), Fraction(1, 32), Fraction(1, 64),
]
TABLE_COMPRESSIONS = [Fraction(1, 8), Fraction(1, 64)]
EXPANSION_FACTORS = [1, 2, 4, 8, 16]
N_IN = 784
BATCH = 50
EVAL_BATCH = 200


def to_hlo_text(lowered) -> str:
    from jax._src.lib import xla_client as xc

    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _cname(c: Fraction) -> str:
    return f"{c.numerator}-{c.denominator}"


def spec_for(method: str, depth: int, hidden: int, out: int, c: Fraction,
             batch: int = BATCH) -> tuple[str, NetSpec, dict]:
    """Resolve a (method, arch, budget) cell to a named NetSpec + metadata."""
    full = sizing.layer_dims(depth, N_IN, hidden, out)
    budgets = sizing.hashed_budgets(full, float(c))
    meta = {
        "depth": depth, "hidden": hidden, "out": out,
        "compression": float(c), "compression_name": _cname(c),
        "virtual_params": sizing.dense_params(full),
    }
    if method in ("nn", "dk"):
        # equivalent-size dense baseline: shrink hidden width to budget
        h_eq = (hidden if c == 1 else
                sizing.equivalent_hidden_width(full, sum(budgets)))
        dims = sizing.layer_dims(depth, N_IN, h_eq, out)
        budgets_used = [(dims[l] + 1) * dims[l + 1] for l in range(len(dims) - 1)]
        meta["hidden_equivalent"] = h_eq
        spec = NetSpec(method=method, dims=tuple(dims), budgets=tuple(budgets_used),
                       batch=batch)
    else:
        spec = NetSpec(method=method, dims=tuple(full), budgets=tuple(budgets),
                       batch=batch)
    name = f"{method}_{depth}l_h{hidden}_o{out}_c{_cname(c)}"
    return name, spec, meta


def expansion_spec_for(method: str, depth: int, base_hidden: int, out: int,
                       factor: int, batch: int = BATCH):
    """Fig. 4 cell: storage fixed to a base_hidden dense net, virtual
    architecture inflated by `factor`."""
    virt, ks = sizing.expansion_dims(depth, N_IN, base_hidden, out, factor)
    if method in ("nn", "dk"):
        dims = sizing.layer_dims(depth, N_IN, base_hidden, out)
        spec = NetSpec(method=method, dims=tuple(dims),
                       budgets=tuple((dims[l] + 1) * dims[l + 1]
                                     for l in range(len(dims) - 1)),
                       batch=batch)
    else:
        spec = NetSpec(method=method, dims=tuple(virt), budgets=tuple(ks), batch=batch)
    meta = {
        "depth": depth, "hidden": base_hidden * factor, "out": out,
        "expansion": factor, "virtual_params": sizing.dense_params(virt),
    }
    name = f"{method}_{depth}l_b{base_hidden}_o{out}_x{factor}"
    return name, spec, meta


def config_sets(hidden: int, exp_base: int) -> dict[str, list]:
    """All named configurations, grouped into artifact sets."""
    core = []
    for method in ("hashnet", "nn"):
        core.append(spec_for(method, 3, hidden, 10, Fraction(1, 8)))
    core.append(spec_for("hashnet", 3, 32, 10, Fraction(1, 4)))  # tiny, tests
    core.append(spec_for("hashnet_dk", 3, 32, 10, Fraction(1, 4)))
    core.append(spec_for("nn", 3, 32, 10, Fraction(1, 1)))  # tiny teacher

    repro = []
    for depth in (3, 5):
        for method in METHODS:
            for c in COMPRESSIONS:
                repro.append(spec_for(method, depth, hidden, 10, c))
            for c in TABLE_COMPRESSIONS:
                repro.append(spec_for(method, depth, hidden, 2, c))
        # teachers for DK (compression 1 dense) — nn_c1-1 already in grid
        # for out=10; add the out=2 teacher:
        repro.append(spec_for("nn", depth, hidden, 2, Fraction(1, 1)))
        # Fig. 4 expansion sweep
        for method in ("hashnet", "rer", "lrd"):
            for f in EXPANSION_FACTORS:
                repro.append(expansion_spec_for(method, depth, exp_base, 10, f))
        repro.append(expansion_spec_for("nn", depth, exp_base, 10, 1))
    return {"core": core, "repro": repro}


def _input_names(spec: NetSpec, pspecs, kind: str) -> list[str]:
    names = [p.name for p in pspecs]
    if kind == "predict":
        return names + ["x"]
    names = names + [f"m_{p.name}" for p in pspecs] + ["x", "y"]
    if spec.uses_soft_targets:
        names.append("soft_targets")
    names += ["seed", "lr", "momentum", "keep_prob"]
    if spec.uses_soft_targets:
        names += ["lam", "temp"]
    return names


def lower_one(task) -> dict:
    """Lower one (name, spec, meta) config to its two HLO files.

    Runs in a worker process; returns the manifest entry.
    """
    name, spec, meta, out_dir, force = task
    entry = {
        "name": name,
        "method": spec.method,
        "dims": list(spec.dims),
        "budgets": list(spec.budgets),
        "batch": spec.batch,
        "seed_base": spec.seed_base,
        "uses_soft_targets": spec.uses_soft_targets,
        **meta,
    }
    pspecs, predict = make_predict(spec)
    _, train = make_train_step(spec)
    entry["params"] = [
        {"name": p.name, "shape": list(p.shape), "init_std": p.init_std}
        for p in pspecs
    ]
    # RER's tensor is dense-but-masked: its logical storage (kept edges,
    # what the paper's size accounting counts) is the budget, not the
    # raw tensor size.
    entry["stored_params"] = (sum(spec.budgets) if spec.method == "rer"
                              else sum(p.count for p in pspecs))
    entry["raw_params"] = sum(p.count for p in pspecs)
    entry["train_inputs"] = _input_names(spec, pspecs, "train")
    entry["predict_inputs"] = _input_names(spec, pspecs, "predict")
    entry["graphs"] = {}
    for kind, fn in (("train", train), ("predict", predict)):
        fname = f"{name}.{kind}.hlo.txt"
        path = os.path.join(out_dir, fname)
        entry["graphs"][kind] = fname
        if not force and os.path.exists(path):
            continue
        args = example_args(spec, pspecs, kind)
        text = to_hlo_text(jax.jit(fn).lower(*args))
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            f.write(text)
        os.replace(tmp, path)
    return entry


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--set", default="core", help="comma list: core,repro")
    ap.add_argument("--hidden", type=int, default=100,
                    help="hidden width for the repro grid (paper: 1000)")
    ap.add_argument("--exp-base", type=int, default=50,
                    help="Fig. 4 base hidden width (paper: 50)")
    ap.add_argument("--jobs", type=int, default=max(1, (os.cpu_count() or 2) - 1))
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args(argv)

    sets = config_sets(args.hidden, args.exp_base)
    chosen: dict[str, tuple] = {}
    for s in args.set.split(","):
        for cfg in sets[s.strip()]:
            chosen[cfg[0]] = cfg  # dedup by name
    if args.list:
        for n in sorted(chosen):
            print(n)
        return 0

    os.makedirs(args.out_dir, exist_ok=True)
    tasks = [(n, spec, meta, args.out_dir, args.force)
             for n, spec, meta in (chosen[k] for k in sorted(chosen))]

    if args.jobs > 1 and len(tasks) > 1:
        with ProcessPoolExecutor(max_workers=args.jobs) as ex:
            entries = list(ex.map(lower_one, tasks))
    else:
        entries = [lower_one(t) for t in tasks]

    # merge with any existing manifest (other sets emitted earlier)
    mpath = os.path.join(args.out_dir, "manifest.json")
    merged: dict[str, dict] = {}
    if os.path.exists(mpath):
        with open(mpath) as f:
            for e in json.load(f)["artifacts"]:
                merged[e["name"]] = e
    for e in entries:
        merged[e["name"]] = e
    with open(mpath, "w") as f:
        json.dump(
            {"version": 1, "n_in": N_IN, "eval_batch": EVAL_BATCH,
             "artifacts": [merged[k] for k in sorted(merged)]},
            f, indent=1)
    print(f"wrote {len(entries)} configs -> {mpath} ({len(merged)} total)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
