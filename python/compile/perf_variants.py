"""Perf-pass tool: emit block-shape variants of one hashed config so the
Rust bench can A/B the L1 tiling (EXPERIMENTS.md §Perf).

Usage: cd python && python -m compile.perf_variants --out-dir ../artifacts
"""

from __future__ import annotations

import argparse
from dataclasses import replace
from fractions import Fraction

from . import aot

BLOCKS = [(64, 128), (128, 256), (128, 785), (256, 256)]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--hidden", type=int, default=100)
    args = ap.parse_args()
    entries = []
    for bn, bm in BLOCKS:
        name, spec, meta = aot.spec_for("hashnet", 3, args.hidden, 10, Fraction(1, 8))
        spec = replace(spec, block_n=bn, block_m=bm)
        name = f"{name}_b{bn}x{bm}"
        entries.append(aot.lower_one((name, spec, meta, args.out_dir, False)))
    # merge into the manifest like aot.main does
    import json
    import os

    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath) as f:
        manifest = json.load(f)
    by_name = {e["name"]: e for e in manifest["artifacts"]}
    for e in entries:
        by_name[e["name"]] = e
    manifest["artifacts"] = [by_name[k] for k in sorted(by_name)]
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"emitted {len(entries)} block variants")


if __name__ == "__main__":
    main()
