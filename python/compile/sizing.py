"""Memory accounting and size-equivalence solvers (paper §6, Baselines).

All methods are compared at an identical number of *stored* parameters.
Given a full architecture ``[n0, h, ..., h, n_out]`` and a compression
factor ``c``:

* **HashNet**:  per-layer budget ``K^l = max(1, round(c * (n^l + 1) * n^{l+1}))``
  (bias column is hashed with the weights, §4.1).
* **NN / DK** (equivalent-size dense): all hidden layers are shrunk at the
  same rate until the stored parameter count equals the budget.
* **RER**: full widths, keep exactly ``K^l`` random edges per layer.
* **LRD**: per-layer rank ``r^l = max(1, round(K^l / (n^l + 1)))`` so the
  *learned* factor ``W in R^{r x (n^l+1)}`` matches the budget (the fixed
  Gaussian factor is hash-generated and counts as free, §6 — "we count the
  fixed low rank matrix ... as taking no memory").
"""

from __future__ import annotations

import math


def layer_dims(depth: int, n_in: int, hidden: int, n_out: int) -> list[int]:
    """Paper nomenclature: a '3-layer' net has 1 hidden layer, '5-layer' has 3."""
    n_hidden = {3: 1, 5: 3}.get(depth)
    if n_hidden is None:
        n_hidden = depth - 2
    return [n_in] + [hidden] * n_hidden + [n_out]


def dense_params(dims: list[int]) -> int:
    """Stored parameters of a fully-connected net (weights + biases)."""
    return sum((dims[l] + 1) * dims[l + 1] for l in range(len(dims) - 1))


def hashed_budgets(dims: list[int], c: float) -> list[int]:
    """Per-layer K^l under compression factor c."""
    return [
        max(1, int(round(c * (dims[l] + 1) * dims[l + 1])))
        for l in range(len(dims) - 1)
    ]


def equivalent_hidden_width(dims: list[int], budget: int) -> int:
    """Largest uniform hidden width whose dense net stores <= budget params.

    Mirrors the paper's 'Neural Network (Equivalent-Size)' baseline: "all
    hidden layers are shrunk at the same rate until the number of stored
    parameters equals the target size".  Solved in closed form (the count
    is quadratic in h for >=2 hidden layers), then adjusted by scan.
    """
    n_in, n_out = dims[0], dims[-1]
    n_hidden = len(dims) - 2
    assert n_hidden >= 1

    def count(h: int) -> int:
        return dense_params([n_in] + [h] * n_hidden + [n_out])

    # closed-form seed: a h^2 + b h + c0 = budget
    a = max(n_hidden - 1, 0)
    b = (n_in + 1) + (n_hidden - 1) + n_out
    c0 = n_out
    if a == 0:
        h = (budget - c0) / b
    else:
        disc = b * b - 4 * a * (c0 - budget)
        h = (-b + math.sqrt(max(disc, 0.0))) / (2 * a)
    h = max(1, int(h))
    while count(h + 1) <= budget:
        h += 1
    while h > 1 and count(h) > budget:
        h -= 1
    return h


def lrd_ranks(dims: list[int], c: float) -> list[int]:
    """Per-layer rank of the learned factor under compression c.

    The learned factor is output-side (`n × r`), so `r = K / n`.
    """
    ks = hashed_budgets(dims, c)
    return [max(1, int(round(k / dims[l + 1]))) for l, k in enumerate(ks)]


def expansion_dims(depth: int, n_in: int, base_hidden: int, n_out: int,
                   factor: int) -> tuple[list[int], list[int]]:
    """Fig. 4 setup: budget fixed to a `base_hidden`-unit dense net; the
    virtual architecture is inflated by `factor`.

    Returns (virtual dims, per-layer K^l). K^l is the dense parameter
    count of layer l at base width — the 'real' weights — while the
    virtual width is ``base_hidden * factor``.
    """
    base = layer_dims(depth, n_in, base_hidden, n_out)
    ks = [(base[l] + 1) * base[l + 1] for l in range(len(base) - 1)]
    virt = layer_dims(depth, n_in, base_hidden * factor, n_out)
    return virt, ks
