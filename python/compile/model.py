"""Layer-2: the paper's model family as build-time JAX.

A small functional framework: a :class:`NetSpec` describes one
(method × architecture × budget) configuration; :func:`build` turns it
into ``(param_specs, apply_fn)``; :func:`make_train_step` /
:func:`make_predict` wrap those into the exact functions that
``aot.py`` lowers to HLO artifacts.

Everything the training loop needs lives *inside* the artifact:

  * forward pass (hashed / dense / masked / low-rank layers, ReLU),
  * inverted dropout driven by a scalar step seed (threefry, stateless),
  * softmax cross-entropy, optionally blended with dark-knowledge soft
    targets (Hinton et al. 2014; Ba & Caruana 2014),
  * backprop (JAX autodiff through the custom-VJP Pallas kernel),
  * SGD-with-momentum parameter update.

The Rust coordinator only marshals buffers and scalars.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable

import jax
import jax.numpy as jnp

from .hashing import layer_seeds, xxh32_u32
from .kernels.hashed_matmul import HashedLayerSpec, make_hashed_matmul
from . import sizing

Params = list[jax.Array]


@dataclass(frozen=True)
class ParamSpec:
    """One stored parameter tensor: name, shape and init scale (He/Glorot
    std the Rust side draws from its own PRNG)."""

    name: str
    shape: tuple[int, ...]
    init_std: float

    @property
    def count(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n


@dataclass(frozen=True)
class NetSpec:
    """Static description of one network configuration."""

    method: str  # hashnet | hashnet_dk | nn | dk | rer | lrd
    dims: tuple[int, ...]  # virtual dims [n_in, h..., n_out]
    budgets: tuple[int, ...]  # per-layer stored-parameter budget K^l
    batch: int = 50
    seed_base: int = 0x9E3779B9
    # Tiling defaults (see EXPERIMENTS.md §Perf): on CPU the interpret-
    # lowered grid is re-fused by XLA so BlockSpec is perf-neutral; the
    # choice targets real-TPU VMEM scheduling (DESIGN.md §8) — full-row
    # m-tiles minimize reduction revisits and fit VMEM comfortably.
    block_n: int = 128
    block_m: int = 1024
    interpret: bool = True
    # ablation: disable the sign hash xi (paper 4.3) in hashed layers
    use_sign: bool = True

    @property
    def n_layers(self) -> int:
        return len(self.dims) - 1

    @property
    def uses_soft_targets(self) -> bool:
        return self.method in ("hashnet_dk", "dk")


def _relu(x):
    return jax.nn.relu(x)


def _augment(a):
    """Append the bias column (the paper hashes biases with the weights)."""
    return jnp.concatenate([a, jnp.ones((a.shape[0], 1), a.dtype)], axis=1)


def _dropout(a, keep_prob, seed, salt: int):
    """Inverted dropout with stateless threefry noise.

    ``seed`` is a traced uint32 scalar (one per train step, supplied by
    the coordinator); ``salt`` distinguishes layers.  ``keep_prob`` is a
    traced f32 scalar so one artifact serves any dropout setting.
    """
    key = jax.random.fold_in(jax.random.PRNGKey(seed), salt)
    mask = jax.random.uniform(key, a.shape) < keep_prob
    return jnp.where(mask, a / keep_prob, 0.0)


# ---------------------------------------------------------------------------
# In-graph generation of fixed (storage-free) auxiliary matrices.
# RER's edge mask and LRD's fixed Gaussian factor are derived from xxh32
# like the HashedNets weights themselves: they cost no artifact constants
# (HLO stays small) and no stored parameters, matching how §6 counts size.
# ---------------------------------------------------------------------------


def _hash_uniform(shape, seed):
    """u32 hash of the index grid -> U(0,1) f32, in-graph."""
    n = 1
    for s in shape:
        n *= s
    keys = jnp.arange(n, dtype=jnp.uint32).reshape(shape)
    h = xxh32_u32(keys, seed, xp=jnp)
    return h.astype(jnp.float32) * jnp.float32(1.0 / 4294967296.0)


def _hash_mask(shape, keep_frac: float, seed):
    """Fixed binary mask keeping ~keep_frac of entries (RER)."""
    return (_hash_uniform(shape, seed) < jnp.float32(keep_frac)).astype(jnp.float32)


def _hash_gaussian(shape, std: float, seed):
    """Fixed Gaussian matrix via Box–Muller over two hash streams (LRD)."""
    u1 = jnp.maximum(_hash_uniform(shape, seed), jnp.float32(1e-7))
    u2 = _hash_uniform(shape, seed ^ 0x5BD1E995)
    z = jnp.sqrt(-2.0 * jnp.log(u1)) * jnp.cos(2.0 * jnp.pi * u2)
    return jnp.float32(std) * z


# ---------------------------------------------------------------------------
# Layer builders: each returns (param_specs, forward) where
# forward(params_slice, a) -> z, with a NOT yet bias-augmented.
# ---------------------------------------------------------------------------


def _hashed_layer(l: int, m: int, n: int, k: int, spec: NetSpec):
    s_h, s_xi = layer_seeds(l, spec.seed_base)
    kspec = HashedLayerSpec(
        M=m + 1, N=n, K=k, seed_h=s_h, seed_xi=s_xi,
        block_n=spec.block_n, block_m=spec.block_m, use_sign=spec.use_sign,
    )
    f = make_hashed_matmul(kspec, interpret=spec.interpret)
    pspecs = [ParamSpec(f"w{l}", (k,), (2.0 / (m + 1)) ** 0.5)]

    def fwd(params: Params, a):
        return f(_augment(a), params[0])

    return pspecs, fwd


def _dense_layer(l: int, m: int, n: int):
    pspecs = [
        ParamSpec(f"W{l}", (n, m), (2.0 / m) ** 0.5),
        ParamSpec(f"b{l}", (n,), 0.0),
    ]

    def fwd(params: Params, a):
        return a @ params[0].T + params[1]

    return pspecs, fwd


def _rer_layer(l: int, m: int, n: int, k: int, spec: NetSpec):
    """Random Edge Removal (Cireşan et al. 2011): full-width dense with a
    fixed random mask keeping k of the (m+1)*n connections."""
    keep = k / float((m + 1) * n)
    s_mask, _ = layer_seeds(1000 + l, spec.seed_base)
    pspecs = [ParamSpec(f"Wm{l}", (n, m + 1), (2.0 / max(keep * (m + 1), 1.0)) ** 0.5)]

    def fwd(params: Params, a):
        mask = _hash_mask((n, m + 1), keep, s_mask)
        return _augment(a) @ (params[0] * mask).T

    return pspecs, fwd


def _lrd_layer(l: int, m: int, n: int, k: int, spec: NetSpec):
    """Low-Rank Decomposition (Denil et al. 2013): V = W @ U.

    The *input-side* factor ``U (r × (m+1))`` is the fixed Gaussian
    (std 1/sqrt(n^l) with n^l inputs, hash-generated, not stored) — a
    random feature projection of the layer input; the *output-side*
    factor ``W (n × r)`` is learned, so the budget gives rank
    ``r = K / n`` (cf. §6: "the low-rank method still randomly projects
    each layer to a random feature space").
    """
    r = max(1, int(round(k / n)))
    s_u, _ = layer_seeds(2000 + l, spec.seed_base)
    pspecs = [ParamSpec(f"Wl{l}", (n, r), (2.0 / r) ** 0.5)]

    def fwd(params: Params, a):
        U = _hash_gaussian((r, m + 1), (m + 1) ** -0.5, s_u)
        return (_augment(a) @ U.T) @ params[0].T

    return pspecs, fwd


# ---------------------------------------------------------------------------


def build(spec: NetSpec) -> tuple[list[ParamSpec], Callable]:
    """Compose the network: returns (param_specs, apply).

    ``apply(params, x, *, train, seed, keep_prob) -> logits`` with dropout
    applied to the *hidden* activations when ``train`` (paper §6 trains
    all models with dropout).
    """
    assert spec.n_layers == len(spec.budgets), (spec.dims, spec.budgets)
    layers = []
    pspecs: list[ParamSpec] = []
    slices = []
    for l in range(spec.n_layers):
        m, n = spec.dims[l], spec.dims[l + 1]
        k = spec.budgets[l]
        if spec.method in ("hashnet", "hashnet_dk"):
            ps, fwd = _hashed_layer(l, m, n, k, spec)
        elif spec.method in ("nn", "dk"):
            ps, fwd = _dense_layer(l, m, n)
        elif spec.method == "rer":
            ps, fwd = _rer_layer(l, m, n, k, spec)
        elif spec.method == "lrd":
            ps, fwd = _lrd_layer(l, m, n, k, spec)
        else:
            raise ValueError(f"unknown method {spec.method}")
        slices.append((len(pspecs), len(pspecs) + len(ps)))
        pspecs.extend(ps)
        layers.append(fwd)

    def apply(params: Params, x, *, train: bool, seed=None, keep_prob=None):
        a = x
        for l, fwd in enumerate(layers):
            z = fwd(params[slices[l][0] : slices[l][1]], a)
            if l < spec.n_layers - 1:
                a = _relu(z)
                if train:
                    a = _dropout(a, keep_prob, seed, salt=l)
            else:
                a = z
        return a

    return pspecs, apply


def softmax_xent(logits, labels):
    """Mean softmax cross-entropy against integer labels."""
    logp = jax.nn.log_softmax(logits)
    ll = jnp.take_along_axis(logp, labels[:, None].astype(jnp.int32), axis=1)
    return -jnp.mean(ll)


def dark_knowledge_loss(logits, labels, soft_targets, lam, temp):
    """Blended DK objective (Hinton et al. 2014):

    ``lam * CE(labels) + (1 - lam) * T^2 * CE(teacher_probs_T, student_T)``.

    ``soft_targets`` are the teacher's *temperature-softened probabilities*
    (computed once by the coordinator with the teacher artifact); lam and
    temp arrive as traced scalars so artifacts stay hyperparameter-free.
    """
    hard = softmax_xent(logits, labels)
    logp_t = jax.nn.log_softmax(logits / temp)
    soft = -jnp.mean(jnp.sum(soft_targets * logp_t, axis=1))
    return lam * hard + (1.0 - lam) * temp * temp * soft


def make_predict(spec: NetSpec):
    """predict(params..., x) -> (logits,)"""
    pspecs, apply = build(spec)

    def predict(*args):
        params = list(args[: len(pspecs)])
        x = args[len(pspecs)]
        return (apply(params, x, train=False),)

    return pspecs, predict


def make_train_step(spec: NetSpec):
    """One SGD-with-momentum step, fully in-graph.

    Signature (flat, in manifest order)::

        train_step(*params, *momenta, x[B,n_in] f32, y[B] i32,
                   [soft_targets[B,n_out] f32,]   # DK methods only
                   seed[] u32, lr[] f32, mom[] f32, keep_prob[] f32,
                   [lam[] f32, temp[] f32])       # DK methods only
          -> (*params', *momenta', loss[])

    Momentum: v' = mom*v - lr*g ; p' = p + v'.
    """
    pspecs, apply = build(spec)
    n_p = len(pspecs)
    dk = spec.uses_soft_targets

    def train_step(*args):
        i = 0
        params = list(args[i : i + n_p]); i += n_p
        momenta = list(args[i : i + n_p]); i += n_p
        x = args[i]; i += 1
        y = args[i]; i += 1
        soft = None
        if dk:
            soft = args[i]; i += 1
        seed = args[i]; i += 1
        lr = args[i]; i += 1
        mom = args[i]; i += 1
        keep_prob = args[i]; i += 1
        if dk:
            lam = args[i]; i += 1
            temp = args[i]; i += 1

        def loss_fn(params):
            logits = apply(params, x, train=True, seed=seed, keep_prob=keep_prob)
            if dk:
                return dark_knowledge_loss(logits, y, soft, lam, temp)
            return softmax_xent(logits, y)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        new_m = [mom * v - lr * g for v, g in zip(momenta, grads)]
        new_p = [p + v for p, v in zip(params, new_m)]
        return (*new_p, *new_m, loss)

    return pspecs, train_step


def example_args(spec: NetSpec, pspecs: list[ParamSpec], kind: str):
    """ShapeDtypeStructs matching the artifact signature, for lowering."""
    f32 = jnp.float32
    sd = jax.ShapeDtypeStruct
    params = [sd(p.shape, f32) for p in pspecs]
    x = sd((spec.batch, spec.dims[0]), f32)
    if kind == "predict":
        return [*params, x]
    y = sd((spec.batch,), jnp.int32)
    scalars = [sd((), jnp.uint32), sd((), f32), sd((), f32), sd((), f32)]
    if spec.uses_soft_targets:
        soft = sd((spec.batch, spec.dims[-1]), f32)
        return [*params, *params, x, y, soft, *scalars, sd((), f32), sd((), f32)]
    return [*params, *params, x, y, *scalars]
