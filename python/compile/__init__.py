"""Build-time compile path: JAX/Pallas → HLO text artifacts.

Never imported at runtime — the Rust coordinator consumes only
``artifacts/*.hlo.txt`` + ``artifacts/manifest.json``.
"""
