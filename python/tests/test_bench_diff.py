"""Unit tests for tools.bench_diff (`make bench-diff`).

Covers both report schemas the repo produces (the util::bench flat
array and the serve_scale object-with-cases), the tolerance-band
direction logic (latency up = bad, throughput down = bad), advisory
vs --strict exit codes, and graceful handling of missing baselines.
"""

import json

import pytest

from tools.bench_diff import Diff, load_cases, main, metric_kind


def write_json(path, doc):
    path.write_text(json.dumps(doc))


def array_report(mean_ns, throughput):
    return [
        {
            "name": "kernel_forward b50",
            "iters": 100,
            "mean_ns": mean_ns,
            "stddev_ns": 10.0,
            "p50_ns": mean_ns,
            "p95_ns": mean_ns * 1.2,
            "throughput": throughput,
        }
    ]


def scale_report(p99_us, rps):
    return {
        "bench": "serve_scale",
        "nofile_limit": 65536,
        "pixels_per_request": 784,
        "cases": [
            {
                "name": "binary c100",
                "protocol": "binary",
                "connections": 100,
                "requests": 2000,
                "errors": 0,
                "p50_us": 500.0,
                "p99_us": p99_us,
                "throughput_rps": rps,
                "truncated": False,
            }
        ],
    }


def bundle_report(mean_ns, throughput, *, v1_bytes=400_000, int8_bytes=102_000):
    """A BENCH_bundle_load.json shard: object schema with numeric file
    sizes at top level and one case per (load path, resident count)."""
    return {
        "bench": "bundle_load",
        "v1_file_bytes": v1_bytes,
        "v2_file_bytes": v1_bytes + 160,
        "v2_int8_file_bytes": int8_bytes,
        "int8_size_ratio": v1_bytes / int8_bytes,
        "cases": [
            {
                "name": name,
                "models": 10,
                "mean_ns": mean_ns,
                "p50_ns": mean_ns,
                "p95_ns": mean_ns * 1.3,
                "throughput": throughput,
                "heap_param_bytes": heap,
                "mapped_file_bytes": mapped,
            }
            for name, heap, mapped in (
                ("v1 copy m=10", 4_000_000, 0),
                ("v2 mmap m=10", 0, 4_000_000),
                ("v2 int8 dequant m=10", 4_000_000, 0),
            )
        ],
    }


def embed_report(mean_ns, throughput):
    """A BENCH_embed_bag.json shard: the util::bench flat array with the
    embed-bag case names (hashed sweep + dense roofline)."""
    return [
        {
            "name": name,
            "iters": 12,
            "mean_ns": mean_ns,
            "stddev_ns": 5.0,
            "p50_ns": mean_ns,
            "p95_ns": mean_ns * 1.1,
            "throughput": throughput,
        }
        for name in (
            "hashed fwd rows=1000000 1/8 bag=50",
            "hashed bwd rows=1000000 1/64 bag=50",
            "dense  fwd rows=100000 bag=50 (roofline)",
        )
    ]


def kernel_report(mean_ns, gflops, *, avx2=1):
    """A BENCH_kernel_forward.json shard: object schema with run
    metadata (avx2 dispatch flag, layer shape) and the tiled/SIMD
    kernel-grid cases carrying a ``gflops`` compute-throughput metric."""
    return {
        "avx2": avx2,
        "m": 784,
        "n": 1000,
        "k": 98125,
        "cases": [
            {
                "name": name,
                "iters": 15,
                "mean_ns": mean_ns,
                "stddev_ns": 8.0,
                "p50_ns": mean_ns,
                "p95_ns": mean_ns * 1.15,
                "throughput": 50.0 / (mean_ns / 1e9),
                "gflops": gflops,
            }
            for name in (
                "scratch b50 784->1000 K=98k",
                "tiled1x8 b50 784->1000 K=98k",
                "tiled8x8 b50 784->1000 K=98k",
            )
        ]
        + [
            # the SIMD primitive rows carry latency only
            {"name": "dot8 dispatch m785", "iters": 15, "mean_ns": 300.0},
            {"name": "dot8 scalar   m785", "iters": 15, "mean_ns": 700.0},
        ],
    }


class TestMetricKind:
    def test_latency_suffixes(self):
        for key in ("mean_ns", "p50_ns", "p99_us", "wall_s", "stddev_ns"):
            assert metric_kind(key) == "latency"

    def test_throughput_markers(self):
        for key in ("throughput", "throughput_rps", "rows_rps", "gflops"):
            assert metric_kind(key) == "throughput"

    def test_everything_else_is_info(self):
        for key in ("iters", "connections", "requests", "errors"):
            assert metric_kind(key) == "info"


class TestLoadCases:
    def test_flat_array_schema(self, tmp_path):
        p = tmp_path / "BENCH_kernel_forward.json"
        write_json(p, array_report(1000.0, 5.0e4))
        cases, meta = load_cases(str(p))
        assert meta == {}
        assert cases["kernel_forward b50"]["mean_ns"] == 1000.0
        assert cases["kernel_forward b50"]["throughput"] == 5.0e4

    def test_object_schema_with_cases(self, tmp_path):
        p = tmp_path / "BENCH_serve_scale.json"
        write_json(p, scale_report(2000.0, 8000.0))
        cases, meta = load_cases(str(p))
        # top-level numeric metadata captured; strings ("bench") are not
        assert meta["pixels_per_request"] == 784
        assert "bench" not in meta
        c = cases["binary c100"]
        assert c["p99_us"] == 2000.0
        # booleans must not be coerced into metrics
        assert "truncated" not in c

    def test_embed_bag_schema(self, tmp_path):
        p = tmp_path / "BENCH_embed_bag.json"
        write_json(p, embed_report(2000.0, 1.6e6))
        cases, meta = load_cases(str(p))
        assert meta == {}
        assert len(cases) == 3
        hashed = cases["hashed fwd rows=1000000 1/8 bag=50"]
        # the gating keys carry the right direction semantics
        assert metric_kind("mean_ns") == "latency"
        assert metric_kind("throughput") == "throughput"
        assert hashed["throughput"] == 1.6e6
        assert cases["dense  fwd rows=100000 bag=50 (roofline)"]["mean_ns"] == 2000.0

    def test_bundle_load_schema(self, tmp_path):
        p = tmp_path / "BENCH_bundle_load.json"
        write_json(p, bundle_report(50_000.0, 200_000.0))
        cases, meta = load_cases(str(p))
        # file sizes ride as numeric metadata; "bench" (a string) does not
        assert meta["v1_file_bytes"] == 400_000
        assert meta["int8_size_ratio"] == pytest.approx(400_000 / 102_000)
        assert "bench" not in meta
        assert len(cases) == 3
        mmap_case = cases["v2 mmap m=10"]
        assert mmap_case["mean_ns"] == 50_000.0
        assert mmap_case["heap_param_bytes"] == 0
        # byte counts are informational — they must never gate
        assert metric_kind("heap_param_bytes") == "info"
        assert metric_kind("mapped_file_bytes") == "info"
        assert metric_kind("v2_int8_file_bytes") == "info"

    def test_kernel_forward_schema(self, tmp_path):
        p = tmp_path / "BENCH_kernel_forward.json"
        write_json(p, kernel_report(1_000_000.0, 80.0))
        cases, meta = load_cases(str(p))
        # the dispatch flag and layer shape ride as numeric metadata
        assert meta["avx2"] == 1
        assert meta["m"] == 784 and meta["n"] == 1000 and meta["k"] == 98125
        tiled = cases["tiled1x8 b50 784->1000 K=98k"]
        assert tiled["gflops"] == 80.0
        assert metric_kind("gflops") == "throughput"
        # the dot8 primitive rows carry latency metrics only
        assert "gflops" not in cases["dot8 dispatch m785"]

    def test_non_json_container_rejected(self, tmp_path):
        p = tmp_path / "BENCH_bad.json"
        p.write_text('"just a string"')
        with pytest.raises(ValueError):
            load_cases(str(p))


class TestToleranceDirections:
    def test_within_band_is_not_a_regression(self):
        d = Diff(tolerance=0.35)
        d.compare_metric("a", "mean_ns", 1000.0, 1200.0)  # +20%
        d.compare_metric("a", "throughput", 100.0, 80.0)  # -20%
        assert d.regressions == []

    def test_latency_increase_past_band_regresses(self):
        d = Diff(tolerance=0.35)
        d.compare_metric("a", "p99_us", 1000.0, 1500.0)  # +50%
        assert len(d.regressions) == 1

    def test_latency_improvement_never_regresses(self):
        d = Diff(tolerance=0.35)
        d.compare_metric("a", "p99_us", 1000.0, 100.0)  # -90%: good
        assert d.regressions == []

    def test_throughput_drop_past_band_regresses(self):
        d = Diff(tolerance=0.35)
        d.compare_metric("a", "throughput_rps", 1000.0, 500.0)  # -50%
        assert len(d.regressions) == 1

    def test_throughput_gain_never_regresses(self):
        d = Diff(tolerance=0.35)
        d.compare_metric("a", "throughput_rps", 1000.0, 9000.0)
        assert d.regressions == []

    def test_info_metrics_never_gate(self):
        d = Diff(tolerance=0.35)
        d.compare_metric("a", "iters", 100.0, 5.0)
        d.compare_metric("a", "errors", 0.0, 50.0)
        assert d.regressions == []

    def test_zero_baseline_does_not_divide(self):
        d = Diff(tolerance=0.35)
        d.compare_metric("a", "p99_us", 0.0, 1000.0)
        assert d.regressions == []


class TestMainCli:
    def run(self, fresh_dir, base_dir, *extra):
        return main(
            ["--fresh", str(fresh_dir), "--baselines", str(base_dir), *extra]
        )

    def test_no_fresh_reports_is_exit_zero(self, tmp_path, capsys):
        fresh, base = tmp_path / "fresh", tmp_path / "base"
        fresh.mkdir(), base.mkdir()
        assert self.run(fresh, base) == 0
        assert "no fresh BENCH_" in capsys.readouterr().out

    def test_missing_baseline_is_skipped_not_failed(self, tmp_path, capsys):
        fresh, base = tmp_path / "fresh", tmp_path / "base"
        fresh.mkdir(), base.mkdir()
        write_json(fresh / "BENCH_x.json", array_report(1000.0, 100.0))
        assert self.run(fresh, base, "--strict") == 0
        assert "no committed baseline" in capsys.readouterr().out

    def test_matching_reports_pass_strict(self, tmp_path, capsys):
        fresh, base = tmp_path / "fresh", tmp_path / "base"
        fresh.mkdir(), base.mkdir()
        write_json(base / "BENCH_x.json", array_report(1000.0, 100.0))
        write_json(fresh / "BENCH_x.json", array_report(1100.0, 95.0))
        assert self.run(fresh, base, "--strict") == 0
        assert "0 regression(s)" in capsys.readouterr().out

    def test_regression_is_advisory_without_strict(self, tmp_path, capsys):
        fresh, base = tmp_path / "fresh", tmp_path / "base"
        fresh.mkdir(), base.mkdir()
        write_json(base / "BENCH_x.json", array_report(1000.0, 100.0))
        write_json(fresh / "BENCH_x.json", array_report(5000.0, 100.0))
        assert self.run(fresh, base) == 0
        assert "REGRESSION" in capsys.readouterr().out

    def test_regression_fails_under_strict(self, tmp_path):
        fresh, base = tmp_path / "fresh", tmp_path / "base"
        fresh.mkdir(), base.mkdir()
        write_json(base / "BENCH_x.json", array_report(1000.0, 100.0))
        write_json(fresh / "BENCH_x.json", array_report(5000.0, 100.0))
        assert self.run(fresh, base, "--strict") == 1

    def test_serve_scale_schema_end_to_end(self, tmp_path):
        fresh, base = tmp_path / "fresh", tmp_path / "base"
        fresh.mkdir(), base.mkdir()
        write_json(base / "BENCH_serve_scale.json", scale_report(2000.0, 8000.0))
        # p99 doubles AND throughput halves — both directions flagged
        write_json(fresh / "BENCH_serve_scale.json", scale_report(4000.0, 4000.0))
        assert self.run(fresh, base, "--strict") == 1

    def test_embed_bag_lookup_throughput_drop_gates_strict(self, tmp_path):
        fresh, base = tmp_path / "fresh", tmp_path / "base"
        fresh.mkdir(), base.mkdir()
        write_json(base / "BENCH_embed_bag.json", embed_report(2000.0, 1.6e6))
        # lookups/sec halves across the sweep — a real regression
        write_json(fresh / "BENCH_embed_bag.json", embed_report(4000.0, 0.8e6))
        assert self.run(fresh, base, "--strict") == 1
        # within-band wobble passes
        write_json(fresh / "BENCH_embed_bag.json", embed_report(2200.0, 1.5e6))
        assert self.run(fresh, base, "--strict") == 0

    def test_bundle_load_latency_regression_gates_strict(self, tmp_path):
        fresh, base = tmp_path / "fresh", tmp_path / "base"
        fresh.mkdir(), base.mkdir()
        write_json(base / "BENCH_bundle_load.json", bundle_report(50_000.0, 200_000.0))
        # mmap load latency doubles — a real regression
        write_json(fresh / "BENCH_bundle_load.json", bundle_report(100_000.0, 100_000.0))
        assert self.run(fresh, base, "--strict") == 1
        # file sizes shifting alone (info metrics) must not gate
        write_json(
            fresh / "BENCH_bundle_load.json",
            bundle_report(52_000.0, 195_000.0, v1_bytes=800_000, int8_bytes=204_000),
        )
        assert self.run(fresh, base, "--strict") == 0

    def test_kernel_gflops_drop_gates_strict(self, tmp_path):
        fresh, base = tmp_path / "fresh", tmp_path / "base"
        fresh.mkdir(), base.mkdir()
        write_json(base / "BENCH_kernel_forward.json", kernel_report(1_000_000.0, 80.0))
        # compute throughput halves (e.g. the avx2 path stopped being
        # taken) — a real regression even if someone also shrank mean_ns
        write_json(fresh / "BENCH_kernel_forward.json", kernel_report(1_000_000.0, 40.0))
        assert self.run(fresh, base, "--strict") == 1
        # within-band wobble passes, avx2 flag drift alone never gates
        write_json(
            fresh / "BENCH_kernel_forward.json",
            kernel_report(1_100_000.0, 75.0, avx2=0),
        )
        assert self.run(fresh, base, "--strict") == 0

    def test_unreadable_fresh_report_is_skipped(self, tmp_path, capsys):
        fresh, base = tmp_path / "fresh", tmp_path / "base"
        fresh.mkdir(), base.mkdir()
        write_json(base / "BENCH_x.json", array_report(1000.0, 100.0))
        (fresh / "BENCH_x.json").write_text("{not json")
        assert self.run(fresh, base, "--strict") == 0
        assert "skipped" in capsys.readouterr().out

    def test_wider_tolerance_absorbs_regression(self, tmp_path):
        fresh, base = tmp_path / "fresh", tmp_path / "base"
        fresh.mkdir(), base.mkdir()
        write_json(base / "BENCH_x.json", array_report(1000.0, 100.0))
        write_json(fresh / "BENCH_x.json", array_report(1500.0, 100.0))
        assert self.run(fresh, base, "--strict") == 1
        assert self.run(fresh, base, "--strict", "--tolerance", "0.6") == 0
