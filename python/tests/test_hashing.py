"""xxh32 correctness: spec goldens, scalar-vs-vectorized bit identity,
bucket uniformity, and the golden vectors shared with the Rust suite."""

import json
import os

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline environment — deterministic fallback sweep
    from _hypothesis_fallback import given, settings, strategies as st

from compile.hashing import (
    golden_vectors,
    hash_grid,
    layer_seeds,
    xxh32,
    xxh32_u32,
    xxh32_u32_scalar,
)


class TestSpecGoldens:
    def test_empty_seed0(self):
        # The one universally published xxh32 sanity value.
        assert xxh32(b"", 0) == 0x02CC5D05

    def test_length_paths(self):
        # exercise <16, ==16, >16 and trailing-byte paths; values are
        # self-consistency checks pinned so regressions are loud.
        data = bytes(range(40))
        h0 = xxh32(data, 0)
        h1 = xxh32(data, 1)
        assert h0 != h1
        assert xxh32(data[:15], 0) != xxh32(data[:16], 0)
        assert xxh32(data[:17], 0) != xxh32(data[:16], 0)


class TestVectorizedAgreesWithScalar:
    @settings(max_examples=200, deadline=None)
    @given(key=st.integers(0, 2**32 - 1), seed=st.integers(0, 2**32 - 1))
    def test_bit_identity(self, key, seed):
        v = int(xxh32_u32(np.array([key], np.uint32), seed)[0])
        assert v == xxh32_u32_scalar(key, seed)

    def test_jnp_matches_numpy(self):
        import jax.numpy as jnp

        keys = np.arange(4096, dtype=np.uint32) * np.uint32(2654435761)  # wraps
        h_np = xxh32_u32(keys, 0x1234)
        h_jnp = np.asarray(xxh32_u32(jnp.asarray(keys), 0x1234, xp=jnp))
        np.testing.assert_array_equal(h_np, h_jnp.astype(np.uint32))


class TestBucketStatistics:
    def test_uniformity_chi_square(self):
        """h(i,j) mod K should be approximately uniform (paper §4.2)."""
        M, N, K = 200, 100, 64
        s_h, s_xi = layer_seeds(3)
        ids, signs = hash_grid(M, N, K, s_h, s_xi)
        counts = np.bincount(ids.reshape(-1), minlength=K)
        expected = M * N / K
        chi2 = float(((counts - expected) ** 2 / expected).sum())
        # df = 63; mean 63, sd ~11. 5-sigma bound.
        assert chi2 < 63 + 5 * np.sqrt(2 * 63), f"chi2={chi2}"

    def test_sign_balance(self):
        M, N = 150, 150
        s_h, s_xi = layer_seeds(1)
        _, signs = hash_grid(M, N, 10, s_h, s_xi)
        frac_pos = float((signs > 0).mean())
        assert 0.48 < frac_pos < 0.52
        assert set(np.unique(signs)) == {-1.0, 1.0}

    def test_layer_seeds_independent(self):
        """Dedicated per-layer hash functions (paper §4.4)."""
        ids0, _ = hash_grid(50, 50, 16, *layer_seeds(0))
        ids1, _ = hash_grid(50, 50, 16, *layer_seeds(1))
        assert (ids0 != ids1).mean() > 0.8

    def test_inner_product_unbiased(self):
        """Eq. 1: E[phi(x)^T phi(x')] = x^T x' over random sign hashes.

        We average the hashed inner product over many independent hash
        seeds and check it approaches the true inner product.
        """
        rng = np.random.RandomState(0)
        m, K, trials = 32, 16, 600
        x = rng.randn(m).astype(np.float32)
        y = rng.randn(m).astype(np.float32)
        acc = 0.0
        for t in range(trials):
            ids, signs = hash_grid(m, 1, K, seed_h=1000 + t, seed_xi=2000 + t)
            ids, signs = ids[0], signs[0]
            phi_x = np.zeros(K, np.float32)
            phi_y = np.zeros(K, np.float32)
            np.add.at(phi_x, ids, signs * x)
            np.add.at(phi_y, ids, signs * y)
            acc += float(phi_x @ phi_y)
        est = acc / trials
        true = float(x @ y)
        # var of single estimate is O(||x||^2 ||y||^2 / K)
        tol = 4 * np.sqrt((x @ x) * (y @ y) / K / trials)
        assert abs(est - true) < tol, f"est={est} true={true} tol={tol}"


class TestGoldenExport:
    def test_golden_vectors_stable_and_exported(self):
        """Write the cross-language golden file consumed by the Rust tests."""
        gv = golden_vectors()
        assert len(gv) == 36
        for key, seed, h in gv:
            assert h == xxh32_u32_scalar(key, seed)
        out = os.path.join(os.path.dirname(__file__), "golden", "xxh32_u32.json")
        os.makedirs(os.path.dirname(out), exist_ok=True)
        with open(out, "w") as f:
            json.dump([{"key": k, "seed": s, "hash": h} for k, s, h in gv], f, indent=1)
