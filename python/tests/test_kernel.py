"""Pallas hashed-matmul kernel vs. pure-jnp oracle — the core L1 signal.

Covers: forward numerics, custom-VJP gradients vs. autodiff-through-the-
oracle, the feature-hashing equivalence (paper §4.3), block-shape
robustness (padded tiles), dtype handling, and hypothesis sweeps over
shapes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline environment — deterministic fallback sweep
    from _hypothesis_fallback import given, settings, strategies as st

from compile.hashing import layer_seeds
from compile.kernels.hashed_matmul import HashedLayerSpec, make_hashed_matmul
from compile.kernels.ref import feature_hash_ref, hashed_matmul_ref, virtual_matrix

SEED_H, SEED_XI = layer_seeds(0)


def _mk(M, N, K, bn=128, bm=256):
    return HashedLayerSpec(M=M, N=N, K=K, seed_h=SEED_H, seed_xi=SEED_XI,
                           block_n=bn, block_m=bm)


def _rand(shape, key, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


class TestForward:
    @pytest.mark.parametrize(
        "B,M,N,K",
        [
            (4, 16, 8, 7),
            (2, 785, 100, 981),          # MNIST-ish layer at 1/8
            (50, 64, 32, 2048),          # K > M*N/one tile
            (1, 3, 5, 2),                # tiny, K=2 heavy collisions
            (8, 130, 129, 100),          # non-multiple of block sizes
        ],
    )
    def test_matches_oracle(self, B, M, N, K):
        spec = _mk(M, N, K)
        f = jax.jit(make_hashed_matmul(spec))
        a = _rand((B, M), key=B * 31 + M)
        w = _rand((K,), key=K)
        got = f(a, w)
        want = hashed_matmul_ref(a, w, N, K, SEED_H, SEED_XI)
        # accumulation order differs between the tiled kernel and the
        # dense oracle; bound is ~eps * sqrt(M) * |a||w| scale
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_block_shapes_equivalent(self):
        """Different tilings must give the same answer (padding masked)."""
        B, M, N, K = 6, 100, 70, 333
        a = _rand((B, M), key=1)
        w = _rand((K,), key=2)
        outs = []
        for bn, bm in [(8, 16), (32, 64), (128, 256), (70, 100), (64, 128)]:
            f = jax.jit(make_hashed_matmul(_mk(M, N, K, bn=bn, bm=bm)))
            outs.append(np.asarray(f(a, w)))
        for o in outs[1:]:
            np.testing.assert_allclose(o, outs[0], rtol=1e-4, atol=1e-5)

    def test_jit_compiles(self):
        spec = _mk(32, 16, 64)
        f = jax.jit(make_hashed_matmul(spec))
        a = _rand((4, 32), key=3)
        w = _rand((64,), key=4)
        np.testing.assert_allclose(
            f(a, w), hashed_matmul_ref(a, w, 16, 64, SEED_H, SEED_XI),
            rtol=1e-5, atol=1e-6,
        )

    def test_compression_one_still_collides_rarely(self):
        """At K = M*N the hash is not a bijection but collisions are few;
        the virtual matrix must still be decompressed consistently."""
        M, N = 24, 16
        K = M * N
        V = np.asarray(virtual_matrix(_rand((K,), key=5), M, N, K, SEED_H, SEED_XI))
        assert V.shape == (N, M)
        # number of distinct buckets used should be close to (1-1/e)*K
        from compile.hashing import hash_grid

        ids, _ = hash_grid(M, N, K, SEED_H, SEED_XI)
        used = len(np.unique(ids))
        assert 0.5 * K < used <= K


class TestFeatureHashEquivalence:
    """Paper §4.3: weight sharing (Eq. 4) == feature hashing (Eq. 5)."""

    @pytest.mark.parametrize("B,M,N,K", [(3, 10, 6, 8), (2, 17, 5, 4)])
    def test_equivalence(self, B, M, N, K):
        a = _rand((B, M), key=11)
        w = _rand((K,), key=12)
        z_ws = hashed_matmul_ref(a, w, N, K, SEED_H, SEED_XI)
        z_fh = feature_hash_ref(a, w, N, K, SEED_H, SEED_XI)
        np.testing.assert_allclose(z_ws, z_fh, rtol=1e-5, atol=1e-5)


class TestGradients:
    @pytest.mark.parametrize("B,M,N,K", [(4, 16, 8, 7), (2, 33, 20, 64), (5, 7, 9, 3)])
    def test_grads_match_oracle(self, B, M, N, K):
        spec = _mk(M, N, K, bn=16, bm=16)
        f = make_hashed_matmul(spec)
        a = _rand((B, M), key=21)
        w = _rand((K,), key=22)
        co = _rand((B, N), key=23)  # cotangent

        def loss_pallas(a, w):
            return jnp.sum(f(a, w) * co)

        def loss_ref(a, w):
            return jnp.sum(hashed_matmul_ref(a, w, N, K, SEED_H, SEED_XI) * co)

        ga_p, gw_p = jax.jit(jax.grad(loss_pallas, argnums=(0, 1)))(a, w)
        ga_r, gw_r = jax.grad(loss_ref, argnums=(0, 1))(a, w)
        np.testing.assert_allclose(ga_p, ga_r, rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(gw_p, gw_r, rtol=1e-4, atol=1e-5)

    def test_grad_w_finite_differences(self):
        """Numerical gradient checking, as the paper does (§6)."""
        B, M, N, K = 3, 12, 6, 5
        f = make_hashed_matmul(_mk(M, N, K, bn=8, bm=8))
        a = _rand((B, M), key=31)
        w = _rand((K,), key=32)

        @jax.jit
        def loss(w):
            return jnp.sum(jnp.tanh(f(a, w)))

        g = np.asarray(jax.grad(loss)(w))
        eps = 1e-3
        for k in range(K):
            e = np.zeros(K, np.float32)
            e[k] = eps
            num = (loss(w + e) - loss(w - e)) / (2 * eps)
            assert abs(num - g[k]) < 5e-3, f"dw[{k}]: fd={num:.5f} ad={g[k]:.5f}"


class TestHypothesisSweep:
    @settings(max_examples=25, deadline=None)
    @given(
        B=st.integers(1, 9),
        M=st.integers(1, 70),
        N=st.integers(1, 50),
        K=st.integers(1, 300),
        bn=st.sampled_from([8, 16, 32, 128]),
        bm=st.sampled_from([8, 16, 64, 256]),
    )
    def test_forward_any_shape(self, B, M, N, K, bn, bm):
        spec = _mk(M, N, K, bn=bn, bm=bm)
        f = jax.jit(make_hashed_matmul(spec))
        a = _rand((B, M), key=B + M * 7)
        w = _rand((K,), key=K)
        got = f(a, w)
        want = hashed_matmul_ref(a, w, N, K, SEED_H, SEED_XI)
        assert got.shape == (B, N)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    @settings(max_examples=10, deadline=None)
    @given(
        B=st.integers(1, 5),
        M=st.integers(2, 30),
        N=st.integers(2, 20),
        K=st.integers(2, 64),
    )
    def test_grads_any_shape(self, B, M, N, K):
        spec = _mk(M, N, K, bn=16, bm=16)
        f = make_hashed_matmul(spec)
        a = _rand((B, M), key=41)
        w = _rand((K,), key=42)
        gw_p = jax.jit(jax.grad(lambda w: jnp.sum(f(a, w) ** 2)))(w)
        gw_r = jax.grad(
            lambda w: jnp.sum(hashed_matmul_ref(a, w, N, K, SEED_H, SEED_XI) ** 2)
        )(w)
        np.testing.assert_allclose(gw_p, gw_r, rtol=1e-3, atol=1e-4)

    def test_bf16_inputs_accumulate_f32(self):
        B, M, N, K = 4, 32, 16, 24
        f = make_hashed_matmul(_mk(M, N, K))
        a = _rand((B, M), key=51).astype(jnp.bfloat16)
        w = _rand((K,), key=52)
        got = f(a, w)
        assert got.dtype == jnp.float32
        want = hashed_matmul_ref(a.astype(jnp.float32), w, N, K, SEED_H, SEED_XI)
        np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)
