"""Deterministic fallback for the `hypothesis` API subset these tests
use (`given`, `settings`, `st.integers`, `st.sampled_from`), for the
offline build environment where hypothesis cannot be installed.

Each `@given` test runs against a fixed number of pseudo-random samples
drawn from a seeded generator, so the sweep is reproducible and the
suite collects/passes without the real dependency. When hypothesis is
available the real library is used instead (see the guarded imports in
the test modules)."""

import random
import zlib


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def draw(self, rng):
        return self._draw(rng)


class strategies:  # mirrors `hypothesis.strategies` usage as `st`
    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def sampled_from(options):
        options = list(options)
        return _Strategy(lambda rng: rng.choice(options))


def settings(max_examples=20, **_ignored):
    def deco(fn):
        fn._max_examples = max_examples
        return fn

    return deco


def given(**strategy_kwargs):
    def deco(fn):
        def wrapper(self, *args):
            # read at call time: @settings sits *above* @given in the
            # test files, so it stamps _max_examples onto this wrapper
            # after @given has run
            max_examples = getattr(wrapper, "_max_examples", None) or getattr(
                fn, "_max_examples", 20
            )
            # stable across processes (hash() is PYTHONHASHSEED-randomized)
            rng = random.Random(0xC0FFEE ^ zlib.crc32(fn.__name__.encode()))
            for _ in range(max_examples):
                drawn = {name: s.draw(rng) for name, s in strategy_kwargs.items()}
                fn(self, *args, **drawn)

        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        return wrapper

    return deco
