"""L2 model framework: shapes, size accounting, losses, training dynamics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import sizing
from compile.model import (
    NetSpec,
    build,
    dark_knowledge_loss,
    example_args,
    make_predict,
    make_train_step,
    softmax_xent,
)


def _init(pspecs, key=0):
    rng = np.random.RandomState(key)
    return [
        jnp.asarray(rng.randn(*p.shape).astype(np.float32) * max(p.init_std, 1e-8))
        for p in pspecs
    ]


def _spec(method, dims=(20, 16, 10), c=0.25, batch=8):
    budgets = sizing.hashed_budgets(list(dims), c)
    if method in ("nn", "dk"):
        budgets = [(dims[l] + 1) * dims[l + 1] for l in range(len(dims) - 1)]
    return NetSpec(method=method, dims=dims, budgets=tuple(budgets), batch=batch,
                   block_n=32, block_m=32)


ALL_METHODS = ["hashnet", "hashnet_dk", "nn", "dk", "rer", "lrd"]


class TestSizing:
    def test_layer_dims(self):
        assert sizing.layer_dims(3, 784, 1000, 10) == [784, 1000, 10]
        assert sizing.layer_dims(5, 784, 1000, 10) == [784, 1000, 1000, 1000, 10]

    def test_dense_params(self):
        # paper fig 4: 3-layer 50-unit net
        assert sizing.dense_params([784, 50, 10]) == 785 * 50 + 51 * 10

    def test_hashed_budgets_respect_compression(self):
        dims = [784, 1000, 10]
        ks = sizing.hashed_budgets(dims, 1 / 8)
        assert ks[0] == round(785 * 1000 / 8)
        assert ks[1] == round(1001 * 10 / 8)

    @pytest.mark.parametrize("depth", [3, 5])
    @pytest.mark.parametrize("c", [1 / 2, 1 / 8, 1 / 64])
    def test_equivalent_width_binds_budget(self, depth, c):
        dims = sizing.layer_dims(depth, 784, 1000, 10)
        budget = sum(sizing.hashed_budgets(dims, c))
        h = sizing.equivalent_hidden_width(dims, budget)
        used = sizing.dense_params(sizing.layer_dims(depth, 784, h, 10))
        over = sizing.dense_params(sizing.layer_dims(depth, 784, h + 1, 10))
        assert used <= budget < over

    def test_expansion_dims_fix_storage(self):
        virt, ks = sizing.expansion_dims(3, 784, 50, 10, 8)
        assert virt == [784, 400, 10]
        assert ks == [785 * 50, 51 * 10]  # stored params never grow


class TestBuild:
    @pytest.mark.parametrize("method", ALL_METHODS)
    def test_forward_shapes(self, method):
        spec = _spec(method)
        pspecs, apply = build(spec)
        params = _init(pspecs)
        x = jnp.ones((spec.batch, spec.dims[0]))
        out = apply(params, x, train=False)
        assert out.shape == (spec.batch, spec.dims[-1])
        assert np.isfinite(np.asarray(out)).all()

    def test_stored_params_match_budget_hashnet(self):
        """HashNet hits any budget exactly — a key selling point."""
        spec = _spec("hashnet", c=1 / 8)
        pspecs, _ = build(spec)
        assert sum(p.count for p in pspecs) == sum(spec.budgets)

    def test_stored_params_lrd_within_rank_quantization(self):
        """LRD can only hit budgets up to rank granularity (r*(m+1))."""
        spec = _spec("lrd", c=1 / 8)
        pspecs, _ = build(spec)
        total = sum(p.count for p in pspecs)
        slack = sum((d + 1) // 2 + 1 for d in spec.dims[:-1])
        assert abs(total - sum(spec.budgets)) <= slack

    def test_rer_logical_storage_is_budget(self):
        """RER's tensor is dense-but-masked; its *logical* storage (kept
        edges, what the paper counts) equals the budget exactly."""
        spec = _spec("rer", c=1 / 8)
        pspecs, apply = build(spec)
        params = [jnp.ones(p.shape, jnp.float32) for p in pspecs]
        # count surviving connections by probing the mask through forward
        from compile.model import _hash_mask
        from compile.hashing import layer_seeds
        kept = 0
        for l in range(spec.n_layers):
            m, n = spec.dims[l], spec.dims[l + 1]
            keep = spec.budgets[l] / float((m + 1) * n)
            s_mask, _ = layer_seeds(1000 + l, spec.seed_base)
            kept += int(np.asarray(_hash_mask((n, m + 1), keep, s_mask)).sum())
        total = sum(spec.budgets)
        assert abs(kept - total) < 0.1 * total  # hash-mask is Bernoulli

    def test_hashnet_param_far_smaller_than_virtual(self):
        spec = _spec("hashnet", dims=(100, 80, 10), c=1 / 16)
        pspecs, _ = build(spec)
        virtual = sizing.dense_params([100, 80, 10])
        assert sum(p.count for p in pspecs) < virtual / 12

    def test_dropout_only_in_train_mode(self):
        spec = _spec("nn")
        pspecs, apply = build(spec)
        params = _init(pspecs)
        x = jnp.ones((spec.batch, spec.dims[0]))
        o1 = apply(params, x, train=False)
        o2 = apply(params, x, train=False)
        np.testing.assert_array_equal(o1, o2)
        t1 = apply(params, x, train=True, seed=jnp.uint32(1), keep_prob=jnp.float32(0.5))
        t2 = apply(params, x, train=True, seed=jnp.uint32(2), keep_prob=jnp.float32(0.5))
        assert np.abs(np.asarray(t1) - np.asarray(t2)).max() > 0

    def test_dropout_deterministic_given_seed(self):
        spec = _spec("hashnet")
        pspecs, apply = build(spec)
        params = _init(pspecs)
        x = jnp.ones((spec.batch, spec.dims[0]))
        kw = dict(train=True, seed=jnp.uint32(7), keep_prob=jnp.float32(0.8))
        np.testing.assert_array_equal(apply(params, x, **kw), apply(params, x, **kw))


class TestLosses:
    def test_xent_matches_manual(self):
        logits = jnp.asarray([[2.0, 0.0, -1.0], [0.0, 1.0, 0.0]])
        y = jnp.asarray([0, 1])
        want = -np.mean(
            [np.log(np.exp(2) / (np.exp(2) + 1 + np.exp(-1))),
             np.log(np.e / (2 + np.e))]
        )
        assert abs(float(softmax_xent(logits, y)) - want) < 1e-6

    def test_dk_loss_reduces_to_hard_at_lam1(self):
        logits = jnp.asarray([[1.0, -1.0], [0.5, 0.5]])
        y = jnp.asarray([0, 1])
        soft = jnp.asarray([[0.9, 0.1], [0.2, 0.8]])
        hard = softmax_xent(logits, y)
        mixed = dark_knowledge_loss(logits, y, soft, jnp.float32(1.0), jnp.float32(4.0))
        assert abs(float(mixed) - float(hard)) < 1e-6

    def test_dk_soft_term_minimized_at_teacher(self):
        y = jnp.asarray([0])
        soft = jnp.asarray([[0.7, 0.3]])
        T = jnp.float32(2.0)

        def soft_loss(l0):
            logits = jnp.asarray([[l0, 0.0]])
            return float(dark_knowledge_loss(logits, y, soft, jnp.float32(0.0), T))

        # minimizing logit gap = T * logit(0.7/0.3)
        best = float(T) * np.log(0.7 / 0.3)
        assert soft_loss(best) < soft_loss(best + 1.0)
        assert soft_loss(best) < soft_loss(best - 1.0)


class TestTrainStep:
    @pytest.mark.parametrize("method", ALL_METHODS)
    def test_loss_decreases(self, method):
        """A few SGD steps on a separable toy problem reduce the loss."""
        spec = _spec(method, dims=(12, 16, 3), c=0.5, batch=16)
        pspecs, train = make_train_step(spec)
        train = jax.jit(train)
        rng = np.random.RandomState(0)
        x = rng.randn(spec.batch, 12).astype(np.float32)
        y = (rng.randint(0, 3, spec.batch)).astype(np.int32)
        x += 2.0 * np.eye(12)[y % 12].astype(np.float32) * 3  # separable signal
        params = _init(pspecs)
        moms = [jnp.zeros_like(p) for p in params]
        extra = ([jnp.ones((spec.batch, 3), jnp.float32) / 3]
                 if spec.uses_soft_targets else [])
        scalars = [jnp.uint32(0), jnp.float32(0.1), jnp.float32(0.9), jnp.float32(1.0)]
        if spec.uses_soft_targets:
            scalars += [jnp.float32(0.7), jnp.float32(2.0)]
        losses = []
        for step in range(30):
            scalars[0] = jnp.uint32(step)
            out = train(*params, *moms, jnp.asarray(x), jnp.asarray(y),
                        *extra, *scalars)
            n = len(params)
            params, moms, loss = list(out[:n]), list(out[n:2 * n]), out[2 * n]
            losses.append(float(loss))
        assert losses[-1] < losses[0] * 0.7, losses[:3] + losses[-3:]
        assert np.isfinite(losses).all()

    def test_momentum_buffers_update(self):
        spec = _spec("hashnet")
        pspecs, train = make_train_step(spec)
        params = _init(pspecs)
        moms = [jnp.zeros_like(p) for p in params]
        x = jnp.ones((spec.batch, spec.dims[0]))
        y = jnp.zeros((spec.batch,), jnp.int32)
        out = jax.jit(train)(*params, *moms, x, y, jnp.uint32(0),
                             jnp.float32(0.1), jnp.float32(0.9), jnp.float32(1.0))
        new_moms = out[len(params): 2 * len(params)]
        assert any(float(jnp.abs(m).max()) > 0 for m in new_moms)

    def test_example_args_arity_matches(self):
        for method in ALL_METHODS:
            spec = _spec(method)
            pspecs, train = make_train_step(spec)
            args = example_args(spec, pspecs, "train")
            zeros = [jnp.zeros(a.shape, a.dtype) for a in args]
            out = train(*zeros)
            assert len(out) == 2 * len(pspecs) + 1
            _, predict = make_predict(spec)
            pargs = example_args(spec, pspecs, "predict")
            pz = [jnp.zeros(a.shape, a.dtype) for a in pargs]
            assert predict(*pz)[0].shape == (spec.batch, spec.dims[-1])
