"""AOT pipeline: config grid, manifest contract, HLO text emission."""

import json
import os
import tempfile
from fractions import Fraction

import pytest

from compile import aot, sizing
from compile.model import NetSpec, example_args, make_predict, make_train_step


class TestConfigGrid:
    def test_repro_set_covers_experiments(self):
        sets = aot.config_sets(hidden=100, exp_base=50)
        names = {c[0] for c in sets["repro"]}
        # figures: all methods x 7 compressions x 2 depths (out=10)
        for method in aot.METHODS:
            for c in aot.COMPRESSIONS:
                for depth in (3, 5):
                    assert f"{method}_{depth}l_h100_o10_c{c.numerator}-{c.denominator}" in names
        # tables: out=2 at 1/8 and 1/64
        assert "hashnet_3l_h100_o2_c1-8" in names
        assert "lrd_5l_h100_o2_c1-64" in names
        # fig4 expansion
        assert "hashnet_3l_b50_o10_x16" in names
        assert "nn_5l_b50_o10_x1" in names
        # out=2 teacher
        assert "nn_3l_h100_o2_c1-1" in names

    def test_core_set_is_small(self):
        sets = aot.config_sets(hidden=100, exp_base=50)
        assert 3 <= len(sets["core"]) <= 8

    def test_spec_for_nn_equivalent_size(self):
        name, spec, meta = aot.spec_for("nn", 3, 1000, 10, Fraction(1, 8))
        # paper: h=1000, 1/8 -> equivalent dense width ~123
        assert 100 < meta["hidden_equivalent"] < 150
        assert spec.dims[1] == meta["hidden_equivalent"]

    def test_spec_for_hashnet_budgets(self):
        _, spec, _ = aot.spec_for("hashnet", 5, 100, 10, Fraction(1, 4))
        dims = sizing.layer_dims(5, 784, 100, 10)
        assert list(spec.budgets) == sizing.hashed_budgets(dims, 0.25)

    def test_expansion_fixes_storage(self):
        for f in (1, 2, 8):
            _, spec, meta = aot.expansion_spec_for("hashnet", 3, 50, 10, f)
            assert sum(spec.budgets) == 785 * 50 + 51 * 10
            assert meta["virtual_params"] == sizing.dense_params([784, 50 * f, 10])


class TestManifestContract:
    def test_input_names_order(self):
        _, spec, _ = aot.spec_for("hashnet_dk", 3, 16, 10, Fraction(1, 2))
        pspecs, _ = make_train_step(spec)
        names = aot._input_names(spec, pspecs, "train")
        assert names == [
            "w0", "w1", "m_w0", "m_w1", "x", "y", "soft_targets",
            "seed", "lr", "momentum", "keep_prob", "lam", "temp",
        ]
        assert aot._input_names(spec, pspecs, "predict") == ["w0", "w1", "x"]

    def test_input_names_match_example_args_arity(self):
        for method in aot.METHODS:
            _, spec, _ = aot.spec_for(method, 3, 12, 10, Fraction(1, 2))
            pspecs, _ = make_predict(spec)
            for kind in ("train", "predict"):
                names = aot._input_names(spec, pspecs, kind)
                args = example_args(spec, pspecs, kind)
                assert len(names) == len(args), (method, kind)


class TestLowering:
    def test_lower_one_emits_hlo_text_and_entry(self):
        name, spec, meta = aot.spec_for("hashnet", 3, 8, 4, Fraction(1, 2), batch=2)
        with tempfile.TemporaryDirectory() as d:
            entry = aot.lower_one((name, spec, meta, d, True))
            for kind in ("train", "predict"):
                path = os.path.join(d, entry["graphs"][kind])
                text = open(path).read()
                assert text.startswith("HloModule"), text[:50]
                assert "ROOT" in text
            assert entry["stored_params"] == sum(spec.budgets)
            assert entry["params"][0]["name"] == "w0"

    def test_lower_one_skips_existing_without_force(self):
        name, spec, meta = aot.spec_for("nn", 3, 6, 4, Fraction(1, 1), batch=2)
        with tempfile.TemporaryDirectory() as d:
            aot.lower_one((name, spec, meta, d, True))
            path = os.path.join(d, f"{name}.train.hlo.txt")
            mtime = os.path.getmtime(path)
            aot.lower_one((name, spec, meta, d, False))
            assert os.path.getmtime(path) == mtime


class TestRealManifest:
    """Invariants over the actually-emitted artifacts/ (if present)."""

    @pytest.fixture
    def manifest(self):
        path = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts",
                            "manifest.json")
        if not os.path.exists(path):
            pytest.skip("artifacts not built")
        with open(path) as f:
            return json.load(f)

    def test_every_entry_has_graph_files(self, manifest):
        base = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
        for a in manifest["artifacts"]:
            for kind in ("train", "predict"):
                assert os.path.exists(os.path.join(base, a["graphs"][kind])), a["name"]

    def test_hashnet_budget_equals_stored(self, manifest):
        for a in manifest["artifacts"]:
            if a["method"] == "hashnet":
                assert a["stored_params"] == sum(a["budgets"]), a["name"]

    def test_compression_accounting(self, manifest):
        for a in manifest["artifacts"]:
            if a["method"] in ("hashnet", "hashnet_dk") and "expansion" not in a:
                ratio = a["stored_params"] / a["virtual_params"]
                assert abs(ratio - a["compression"]) < 0.02, a["name"]
