# HashedNets — build / test / bench entry points.
#
#   make check      build (release) + clippy (-D warnings) + the full
#                   Rust test suite. Deterministic on a fresh checkout:
#                   artifact-dependent tests skip gracefully when
#                   artifacts/ is absent.
#   make bench      run every bench target; each writes BENCH_<name>.json
#                   at the repo root so the perf trajectory is tracked
#                   across PRs.
#   make serve-bench  run only the serving latency sweep (native 1/2/4
#                   workers vs runtime) and collect BENCH_serve_latency.json.
#   make artifacts  lower the core config set to HLO artifacts (needs
#                   the Python/JAX toolchain).
#   make pytest     run the Python build-time test suite (also emits the
#                   golden hash vectors the Rust tests cross-check).

RUST_DIR := rust
PY_DIR   := python

.PHONY: check bench serve-bench artifacts pytest clean-bench

check:
	cd $(RUST_DIR) && cargo build --release && cargo clippy -q --all-targets -- -D warnings && cargo test -q

# bench binaries anchor artifacts/ and BENCH_*.json at the repo root
# via CARGO_MANIFEST_DIR, so they are CWD-independent
bench:
	cd $(RUST_DIR) && cargo bench
	@echo "== collected bench reports =="
	@ls -l BENCH_*.json 2>/dev/null || echo "no BENCH_*.json produced"

serve-bench:
	cd $(RUST_DIR) && cargo bench --bench serve_latency
	@echo "== serve latency report =="
	@ls -l BENCH_serve_latency.json 2>/dev/null || echo "no BENCH_serve_latency.json produced"

artifacts:
	cd $(PY_DIR) && python -m compile.aot --out-dir ../artifacts --set core

pytest:
	cd $(PY_DIR) && python -m pytest -q tests

clean-bench:
	rm -f BENCH_*.json
