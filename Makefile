# HashedNets — build / test / bench entry points.
#
#   make check      build (release) + clippy (-D warnings) + the full
#                   Rust test suite. Deterministic on a fresh checkout:
#                   artifact-dependent tests skip gracefully when
#                   artifacts/ is absent.
#   make bench      run every bench target; each writes BENCH_<name>.json
#                   at the repo root so the perf trajectory is tracked
#                   across PRs.
#   make serve-bench  run only the serving latency sweep (native 1/2/4
#                   workers vs runtime) and collect BENCH_serve_latency.json.
#   make train-bench  run only the training throughput sweep (threaded
#                   backward at 1/2/4 workers, batch 50, the legacy
#                   scatter-vs-inverse-plan Eq. 12 baseline, plus the
#                   ordered-reduction overhead) and collect
#                   BENCH_train_throughput.json.
#   make pool-bench run only the PoolExec dispatch-overhead comparison
#                   (parked pool vs cold spawn/join) and collect
#                   BENCH_pool_overhead.json.
#   make serve-scale-bench  connection-scale sweep of the event-loop
#                   front end (100/1k/10k concurrent connections ×
#                   JSON vs binary framing) → BENCH_serve_scale.json.
#                   HN_SERVE_SCALE_CONNS / HN_SERVE_SCALE_REQS shrink
#                   it for CI smoke.
#   make embed-bench  sparse embedding-bag sweep (≥1M virtual rows at
#                   bag sizes 10/50/200 vs the dense-table roofline at
#                   compression 1/8–1/64) → BENCH_embed_bag.json.
#                   HN_EMBED_BENCH_ROWS / HN_EMBED_BENCH_NBAGS shrink
#                   it for CI smoke.
#   make bundle-bench  HNMB v1 read-copy vs v2 mmap load-latency and
#                   resident-bytes sweep at 1/10/50/200 resident models
#                   (plus int8 dequantize-on-load) → BENCH_bundle_load.json.
#                   HN_BUNDLE_BENCH_MODELS shrinks it for CI smoke.
#   make kernel-bench  hashed forward-kernel grid (gather / scratch /
#                   tiled TilePlan / bucket / inverse vs the dense
#                   roofline, plus the dot8 SIMD-vs-scalar primitive)
#                   at batch 1/50 → BENCH_kernel_forward.json.
#                   HN_KERNEL_BENCH_DIMS / HN_KERNEL_BENCH_ITERS shrink
#                   it for CI smoke.
#   make bench-diff compare freshly produced BENCH_*.json against the
#                   committed baselines in benches/baselines/ with
#                   per-metric tolerance bands (see
#                   python/tools/bench_diff.py; non-blocking advisory
#                   unless --strict).
#   make smoke      tiny end-to-end train→bundle→serve→hot-load loop on
#                   the native stack (no artifacts needed); also runs
#                   as the last step of `make check`.
#   make soak       the chaos soak: concurrent clients × seeded fault
#                   injection (errors/latency/panics) × hot-(re)load
#                   churn, asserting every request gets exactly one
#                   explicit reply and no worker dies. #[ignore]d so
#                   tier-1 `make check` stays fast.
#   make artifacts  lower the core config set to HLO artifacts (needs
#                   the Python/JAX toolchain).
#   make pytest     run the Python build-time test suite (also emits the
#                   golden hash vectors the Rust tests cross-check).

RUST_DIR := rust
PY_DIR   := python

.PHONY: check bench serve-bench train-bench pool-bench serve-scale-bench embed-bench bundle-bench kernel-bench bench-diff artifacts pytest smoke soak clean-bench

# docs are load-bearing: rustdoc runs with -D warnings (broken intra-doc
# links fail the build) and the doc-examples on ModelSpec / ModelBundle /
# TrainOptions execute under `cargo test --doc`, so the paper-mapping
# documentation can never rot.
check:
	cd $(RUST_DIR) && cargo build --release && cargo clippy -q --all-targets -- -D warnings && cargo test -q
	cd $(RUST_DIR) && RUSTDOCFLAGS="-D warnings" cargo doc -q --no-deps && cargo test -q --doc
	$(MAKE) smoke

# tiny end-to-end loop on the native stack: train from a pure spec →
# save a ModelBundle → serve it → classify over TCP → hot-load a second
# bundle into the running server → reload/unload → shutdown.
# Needs no artifacts, no Python — deterministic on a fresh checkout.
smoke:
	cd $(RUST_DIR) && cargo run --release --quiet -- smoke

# the chaos soak test (see rust/tests/serve_chaos.rs) — long-running,
# run on demand and as a non-blocking CI job
soak:
	cd $(RUST_DIR) && cargo test --release --test serve_chaos -- --ignored --nocapture

# bench binaries anchor artifacts/ and BENCH_*.json at the repo root
# via CARGO_MANIFEST_DIR, so they are CWD-independent
bench:
	cd $(RUST_DIR) && cargo bench
	@echo "== collected bench reports =="
	@ls -l BENCH_*.json 2>/dev/null || echo "no BENCH_*.json produced"

serve-bench:
	cd $(RUST_DIR) && cargo bench --bench serve_latency
	@echo "== serve latency report =="
	@ls -l BENCH_serve_latency.json 2>/dev/null || echo "no BENCH_serve_latency.json produced"

train-bench:
	cd $(RUST_DIR) && cargo bench --bench train_throughput
	@echo "== train throughput report =="
	@ls -l BENCH_train_throughput.json 2>/dev/null || echo "no BENCH_train_throughput.json produced"

pool-bench:
	cd $(RUST_DIR) && cargo bench --bench pool_overhead
	@echo "== pool overhead report =="
	@ls -l BENCH_pool_overhead.json 2>/dev/null || echo "no BENCH_pool_overhead.json produced"

serve-scale-bench:
	cd $(RUST_DIR) && cargo bench --bench serve_scale
	@echo "== serve scale report =="
	@ls -l BENCH_serve_scale.json 2>/dev/null || echo "no BENCH_serve_scale.json produced"

embed-bench:
	cd $(RUST_DIR) && cargo bench --bench embed_bag
	@echo "== embed bag report =="
	@ls -l BENCH_embed_bag.json 2>/dev/null || echo "no BENCH_embed_bag.json produced"

bundle-bench:
	cd $(RUST_DIR) && cargo bench --bench bundle_load
	@echo "== bundle load report =="
	@ls -l BENCH_bundle_load.json 2>/dev/null || echo "no BENCH_bundle_load.json produced"

kernel-bench:
	cd $(RUST_DIR) && cargo bench --bench kernel_forward
	@echo "== kernel forward report =="
	@ls -l BENCH_kernel_forward.json 2>/dev/null || echo "no BENCH_kernel_forward.json produced"

# compare fresh BENCH_*.json against benches/baselines/ — advisory by
# default (machines differ); BENCH_DIFF_FLAGS="--strict" gates on it
bench-diff:
	cd $(PY_DIR) && python -m tools.bench_diff --fresh .. --baselines ../benches/baselines $(BENCH_DIFF_FLAGS)

artifacts:
	cd $(PY_DIR) && python -m compile.aot --out-dir ../artifacts --set core

pytest:
	cd $(PY_DIR) && python -m pytest -q tests

clean-bench:
	rm -f BENCH_*.json
